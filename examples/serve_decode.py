"""Serving example: batched prefill + autoregressive decode with KV cache.

Uses the assembled super-network (what SuperSFL trains) to serve a batch of
requests: one prefill over the prompts, then token-by-token decode —
exercising the same ``prefill_step`` / ``serve_step`` the dry-run lowers for
the decode_32k / long_500k shapes (rolling-window cache included).

Run: PYTHONPATH=src python examples/serve_decode.py [arch]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base
from repro.configs.base import InputShape
from repro.models import decode as D
from repro.models import model as M


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "mixtral_8x7b"
    cfg = base.get_reduced(arch)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)

    B, prompt_len, gen_len = 4, 24, 16
    npatch = cfg.n_patches if cfg.family == "vlm" else 0
    batch = M.make_dummy_batch(
        cfg, InputShape("serve", prompt_len + npatch, B, "prefill"), rng)

    prefill = jax.jit(lambda p, b: D.prefill(cfg, p, b,
                                             decode_budget=gen_len))
    step = jax.jit(lambda p, c, t: D.decode_step(cfg, p, c, t))

    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab], axis=-1).astype(jnp.int32)
    outs = [np.asarray(tok)]
    for _ in range(gen_len - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    gen = np.concatenate(outs, axis=1)
    print(f"arch={cfg.name}  batch={B}  prompt={prompt_len}  "
          f"generated={gen.shape[1]} tokens")
    cache_kind = [k for k in ("k", "ssm_h") if k in cache]
    print("cache kinds:", cache_kind, " window:",
          cache["k"].shape[2] if "k" in cache else "-")
    for b in range(min(2, B)):
        print(f"  req{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()

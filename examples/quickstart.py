"""Quickstart: the SuperSFL pipeline end-to-end in ~60 seconds on CPU.

1. Builds the paper's setting at reduced scale: a ViT backbone supernet,
   a heterogeneous fleet (mem ~ U[2,16] GB, lat ~ U[20,200] ms),
   Eq.1 resource-aware depth allocation, Dirichlet(0.5) non-IID data.
2. Assembles an ``Engine`` with the builder API: pick a strategy from the
   registry (ssfl / sfl / dfl / fedavg / fedavgm / fedadam / fedyogi /
   unstable / async_buffered / hasfl — or your own ``@register_strategy``
   class, see docs/strategies.md), an optimizer from ``repro.optim``, and
   the scenario knobs (server availability, per-round client sampling,
   participation arrival processes).
3. Runs a few SuperSFL rounds (TPGF + fault tolerance + Eq.6/8 aggregation)
   and prints accuracy, communication cost, and the depth histogram.

Run: PYTHONPATH=src python examples/quickstart.py
     (--rounds/--clients/--strategy shrink or reroute it; CI smoke-runs
      ``--rounds 2 --clients 4``)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import base
from repro.federated import Engine, available_strategies


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--strategy", default="ssfl",
                    choices=available_strategies())
    args = ap.parse_args()

    cfg = base.get_reduced("vit16_cifar").replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, image_size=16)

    print("registered strategies:", available_strategies())
    engine = (Engine.builder(cfg)
              .clients(args.clients, availability=0.9, sample_frac=1.0)
              .strategy(args.strategy)
              .optimizer("sgd", lr=0.25)
              .rounds(local_steps=3, batch_size=32, seed=0)
              .build())

    depths = engine.state.fleet.depths
    print("client depth allocation (Eq. 1):",
          dict(zip(*map(list, np.unique(depths, return_counts=True)))))

    for r in range(args.rounds):
        rec = engine.run_round()
        if (r + 1) % 2 == 0 or r == args.rounds - 1:
            acc = engine.evaluate()
            print(f"round {rec['round']:2d}  fused_loss={rec['loss']:.3f}  "
                  f"test_acc={acc:.3f}  comm={rec['comm_mb']:.1f} MB")
    print("\nledger:", engine.accountant.summary())


if __name__ == "__main__":
    main()

"""Quickstart: the SuperSFL pipeline end-to-end in ~60 seconds on CPU.

1. Builds the paper's setting at reduced scale: a ViT backbone supernet,
   a heterogeneous fleet (mem ~ U[2,16] GB, lat ~ U[20,200] ms),
   Eq.1 resource-aware depth allocation, Dirichlet(0.5) non-IID data.
2. Runs a few SuperSFL rounds (TPGF + fault tolerance + Eq.6/8 aggregation).
3. Prints accuracy, communication cost, and the allocated depth histogram.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import base
from repro.federated.round import FederatedTrainer


def main():
    cfg = base.get_reduced("vit16_cifar").replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, image_size=16)
    trainer = FederatedTrainer(cfg, n_clients=8, method="ssfl", seed=0,
                               lr=0.25, local_steps=3, batch_size=32,
                               availability=0.9)

    depths = trainer.fleet.depths
    print("client depth allocation (Eq. 1):",
          dict(zip(*map(list, np.unique(depths, return_counts=True)))))

    for r in range(10):
        rec = trainer.run_round()
        if (r + 1) % 2 == 0:
            acc = trainer.evaluate()
            print(f"round {rec['round']:2d}  fused_loss={rec['loss']:.3f}  "
                  f"test_acc={acc:.3f}  comm={rec['comm_mb']:.1f} MB")
    s = trainer.accountant.summary()
    print("\nledger:", s)


if __name__ == "__main__":
    main()

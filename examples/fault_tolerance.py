"""Fault-tolerance demo (paper §II-C / Table III).

Trains SuperSFL under decreasing server-gradient availability and shows the
graceful degradation the paper reports: accuracy falls off smoothly instead
of collapsing, because clients keep learning through their local classifier
and their fallback updates re-enter aggregation.

Run: PYTHONPATH=src python examples/fault_tolerance.py [n_rounds]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import base
from repro.federated import Engine


def main():
    n_rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 14
    cfg = base.get_reduced("vit16_cifar").replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, image_size=16)
    print(f"{'availability':>14s} {'mode':>26s} {'final_acc':>10s}")
    modes = {1.0: "fully server-assisted", 0.7: "mostly server-assisted",
             0.5: "partially server-assisted", 0.2: "mostly client-driven",
             0.0: "serverless"}
    for frac, mode in modes.items():
        # engine.evaluate() falls back to the per-client local-head
        # ensemble when the server head was never trained (the 0.0 row)
        eng = Engine(cfg, 8, "ssfl", seed=3, lr=0.25, local_steps=3,
                     batch_size=32, availability=frac)
        for _ in range(n_rounds):
            eng.run_round()
        print(f"{frac:14.1f} {mode:>26s} {eng.evaluate():10.3f}")


if __name__ == "__main__":
    main()

"""E2E driver: SuperSFL split-training of an assigned LLM architecture.

This is the runnable face of the production ``train_step`` — the exact
function the multi-pod dry-run lowers for the 10 x 4 matrix. On this CPU
container it runs the reduced variant for a few hundred steps and shows the
TPGF losses falling; on a v5e pod the same command with ``--mesh`` and no
``--reduced`` trains the full config.

Run: PYTHONPATH=src python examples/train_lm_supersfl.py [arch]
"""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3_2_3b"
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", arch,
           "--reduced", "--steps", "200", "--batch", "8", "--seq", "64",
           "--lr", "3e-3", "--log-every", "25",
           "--ckpt", "results/quickckpt"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    raise SystemExit(subprocess.call(cmd, cwd=ROOT, env=env))


if __name__ == "__main__":
    main()

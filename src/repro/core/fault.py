"""Fault-tolerant client-side execution — paper §II-C / Algorithm 3.

The paper's mechanism is a 5 s RPC timeout; its *evaluation* (Table III)
is a server-gradient-availability fraction. We model availability directly:
per (client, round) Bernoulli draws (or a fixed fraction schedule), which is
what the ablation sweeps. When the server is unavailable the client runs the
Phase-1-only local update and its params still enter the next aggregation
round (weighted by Eq. 6 with the client loss — no fused loss available).
"""
from __future__ import annotations

import numpy as np


class AvailabilityModel:
    """Draws server reachability per (client, round)."""

    def __init__(self, fraction: float = 1.0, seed: int = 0):
        assert 0.0 <= fraction <= 1.0
        self.fraction = fraction
        self._rng = np.random.default_rng(seed)

    def draw(self, n_clients: int) -> np.ndarray:
        if self.fraction >= 1.0:
            return np.ones(n_clients, bool)
        if self.fraction <= 0.0:
            return np.zeros(n_clients, bool)
        return self._rng.random(n_clients) < self.fraction


class TimeoutAvailability(AvailabilityModel):
    """Latency-threshold variant: server 'times out' for clients whose
    round-trip latency exceeds ``timeout_ms`` (deterministic analogue of the
    paper's 5 s RPC timeout, scaled to the simulated [20, 200] ms range)."""

    def __init__(self, latencies_ms, timeout_ms: float, jitter_ms: float = 0.0,
                 seed: int = 0):
        super().__init__(1.0, seed)
        self.lat = np.asarray(latencies_ms, float)
        self.timeout_ms = timeout_ms
        self.jitter_ms = jitter_ms

    def draw(self, n_clients: int) -> np.ndarray:
        jitter = (self._rng.normal(0.0, self.jitter_ms, n_clients)
                  if self.jitter_ms else 0.0)
        return (self.lat[:n_clients] + jitter) <= self.timeout_ms

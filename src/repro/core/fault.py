"""Client/server arrival processes — paper §II-C / Algorithm 3, generalized.

The paper's fault mechanism is a 5 s RPC timeout; its *evaluation*
(Table III) is a server-gradient-availability fraction. The seed modeled
that directly as per-(client, round) Bernoulli draws. Scenario strategies
(unstable participation, Wei et al.) need richer temporal structure, so the
engine now owns a small ``ArrivalProcess`` hierarchy:

  ``ArrivalProcess``        — the protocol: ``draw(n) -> bool [n]`` once per
                              round, plus ``get_state``/``set_state`` so a
                              checkpointed run resumes bit-identically.
  ``AvailabilityModel``     — the Bernoulli special case (i.i.d. across
                              clients and rounds); the seed behaviour.
  ``TimeoutAvailability``   — deterministic latency-threshold variant of the
                              paper's RPC timeout.
  ``MarkovArrivalProcess``  — per-client on/off Markov chain (Gilbert
                              model) with configurable up/down transition
                              rates and an optional per-round deadline-
                              straggler draw.

The same abstraction serves both masks the engine draws each round: server
*availability* (can a participant reach the server?) and client
*participation* (did the client show up at all?).
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np


class ArrivalProcess:
    """One boolean draw per (client, round); stateful across rounds.

    Subclasses override :meth:`draw`. Processes carrying extra state beyond
    their RNG (e.g. the Markov on/off vector) must extend
    :meth:`get_state` / :meth:`set_state` — both use JSON-able payloads so
    checkpoint manifests can embed them (see ``Engine.save``).
    """

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def draw(self, n_clients: int) -> np.ndarray:
        raise NotImplementedError

    # ----------------------------------------------------- resume support
    def get_state(self) -> Dict[str, Any]:
        return {"rng": self._rng.bit_generator.state}

    def set_state(self, state: Dict[str, Any]) -> None:
        self._rng.bit_generator.state = state["rng"]


class AvailabilityModel(ArrivalProcess):
    """Bernoulli special case: i.i.d. ``fraction`` draws per (client, round).

    ``fraction=1.0`` / ``0.0`` short-circuit without consuming randomness,
    so always-on runs are bit-identical to never drawing at all.
    """

    def __init__(self, fraction: float = 1.0, seed: int = 0):
        assert 0.0 <= fraction <= 1.0
        super().__init__(seed)
        self.fraction = fraction

    def draw(self, n_clients: int) -> np.ndarray:
        if self.fraction >= 1.0:
            return np.ones(n_clients, bool)
        if self.fraction <= 0.0:
            return np.zeros(n_clients, bool)
        return self._rng.random(n_clients) < self.fraction


class TimeoutAvailability(AvailabilityModel):
    """Latency-threshold variant: server 'times out' for clients whose
    round-trip latency exceeds ``timeout_ms`` (deterministic analogue of the
    paper's 5 s RPC timeout, scaled to the simulated [20, 200] ms range)."""

    def __init__(self, latencies_ms, timeout_ms: float, jitter_ms: float = 0.0,
                 seed: int = 0):
        super().__init__(1.0, seed)
        self.lat = np.asarray(latencies_ms, float)
        self.timeout_ms = timeout_ms
        self.jitter_ms = jitter_ms

    def draw(self, n_clients: int) -> np.ndarray:
        jitter = (self._rng.normal(0.0, self.jitter_ms, n_clients)
                  if self.jitter_ms else 0.0)
        return (self.lat[:n_clients] + jitter) <= self.timeout_ms


class MarkovArrivalProcess(ArrivalProcess):
    """Per-client on/off (Gilbert) chain with a deadline-straggler overlay.

    Each client holds a binary state; per round it transitions
    off -> on with probability ``p_up`` and on -> off with ``p_down``.
    The chain starts from its stationary distribution
    ``pi_on = p_up / (p_up + p_down)``, so the *marginal* on-fraction equals
    ``pi_on`` from round 0 (the property ``tests/test_scenarios.py`` pins).

    ``straggle_p`` models deadline misses (Wei et al.): a client whose chain
    is *on* still sits out the round with probability ``straggle_p`` — the
    draw is per-round and does NOT change the chain state, i.e. a straggler
    is late, not gone.
    """

    def __init__(self, p_up: float = 0.5, p_down: float = 0.2,
                 straggle_p: float = 0.0, seed: int = 0):
        assert 0.0 < p_up <= 1.0 and 0.0 <= p_down <= 1.0
        assert 0.0 <= straggle_p < 1.0
        super().__init__(seed)
        self.p_up, self.p_down, self.straggle_p = p_up, p_down, straggle_p
        self._up: np.ndarray = None   # lazily sized on first draw

    @property
    def stationary_fraction(self) -> float:
        return self.p_up / (self.p_up + self.p_down)

    def draw(self, n_clients: int) -> np.ndarray:
        if self._up is None or len(self._up) != n_clients:
            self._up = self._rng.random(n_clients) < self.stationary_fraction
        else:
            u = self._rng.random(n_clients)
            self._up = np.where(self._up, u >= self.p_down, u < self.p_up)
        joined = self._up.copy()
        if self.straggle_p > 0.0:
            joined &= self._rng.random(n_clients) >= self.straggle_p
        return joined

    def get_state(self) -> Dict[str, Any]:
        s = super().get_state()
        s["up"] = None if self._up is None else self._up.astype(int).tolist()
        return s

    def set_state(self, state: Dict[str, Any]) -> None:
        super().set_state(state)
        up = state.get("up")
        self._up = None if up is None else np.asarray(up, bool)

"""Three-Phase Gradient Fusion (TPGF) — paper §II-B / Algorithm 2.

Phase 1 (client): local head loss, phi_i update grad, clipped encoder grad.
Phase 2 (server): suffix loss, server param grads, g_z returned to client,
                  client backprop of g_z through the encoder (one shared
                  ``jax.vjp`` of the prefix — exactly Algorithm 2 line 13).
Phase 3 (client): loss-weighted fusion (Eq. 3/4) of the two encoder grads.

Everything returns *gradients*; the optimizer application lives in
``repro.optim`` so the same step works under SGD/AdamW and under pjit.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import supernet as SN
from repro.models import model as M


class TPGFOut(NamedTuple):
    grads: Dict[str, Any]        # full-params-aligned gradient tree
    loss_client: jnp.ndarray
    loss_server: jnp.ndarray
    w_client: jnp.ndarray
    aux: jnp.ndarray             # MoE router load-balance loss


def tpgf_weight(loss_client, loss_server, d_i: int, d_s: int,
                eps: float = 1e-8, variant: str = "full"):
    """Eq. (3): depth-aware x inverse-loss reliability weighting.

    ``variant`` implements the paper's Fig. 6 ablation:
      full     — both factors (the paper's rule)
      no_loss  — depth factor only
      no_depth — loss factor only
      equal    — neither (naive 0.5/0.5 fusion)
    """
    depth = d_i / (d_i + d_s)
    ic = 1.0 / (loss_client + eps)
    is_ = 1.0 / (loss_server + eps)
    loss_term = ic / (ic + is_)
    if variant == "full":
        return depth * loss_term
    if variant == "no_loss":
        return depth + 0.0 * loss_term          # depth fraction alone
    if variant == "no_depth":
        return loss_term                         # reliability alone
    if variant == "equal":
        return 0.5 + 0.0 * loss_term             # naive average
    raise ValueError(variant)


def fused_loss(loss_client, loss_server, d_i: int, d_s: int,
               eps: float = 1e-8, variant: str = "full"):
    """The same fusion rule applied to losses (used by Eq. 6 aggregation).

    ``variant`` must match the ``cfg.tpgf_variant`` the gradients were
    fused under, or the recorded Eq. 6 weights disagree with the update
    actually applied (the Fig. 6 ablation bug)."""
    w = tpgf_weight(loss_client, loss_server, d_i, d_s, eps, variant)
    return w * loss_client + (1.0 - w) * loss_server


def _fault_degrade(server_available, w_c, g_server_params, g_client,
                   g_client_local):
    """Fault-tolerant degrade shared by both TPGF entry points (paper
    §II-C): where the server is unreachable this step, the fusion weight
    collapses to 1, the encoder takes its local-only (Phase-1) gradient,
    and the server branch gets zero gradient. ``server_available=None``
    means the caller never degrades — everything passes through."""
    if server_available is None:
        return w_c, g_server_params, g_client
    w_c = jnp.where(server_available, w_c, 1.0)
    g_server_params = jax.tree.map(
        lambda g: jnp.where(server_available, g, jnp.zeros_like(g)),
        g_server_params)
    g_client = jax.tree.map(
        lambda fused, loc: jnp.where(server_available, fused, loc),
        g_client, g_client_local)
    return w_c, g_server_params, g_client


def clip_by_global_l2(tree, tau: float):
    """Paper's Phase-1 encoder-gradient clip (tau = 0.5)."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, tau / (norm + 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


def fuse_gradients(g_client, g_server, w_client, *, use_pallas: bool = False):
    """Eq. (4): per-leaf fused encoder gradient."""
    w_c = w_client.astype(jnp.float32)
    if use_pallas:
        from repro.kernels.tpgf_fusion.ops import fuse_tree
        return fuse_tree(g_client, g_server, w_c)
    return jax.tree.map(
        lambda a, b: (w_c * a.astype(jnp.float32)
                      + (1.0 - w_c) * b.astype(jnp.float32)).astype(a.dtype),
        g_client, g_server)


def tpgf_grads(cfg: ModelConfig, params, batch, d, *,
               server_available=None) -> TPGFOut:
    """One TPGF iteration's gradients for all parameter groups.

    ``server_available``: optional bool scalar. When False this degrades to
    the fault-tolerant Phase-1-only update (paper §II-C): encoder+phi_i get
    local gradients, server params get zero.

    ``d`` may be a jax scalar: the runtime-depth form delegates to
    :func:`tpgf_grads_split` over full-``L`` views (masked scans), then
    row-selects the two stack gradients back into one tree — the active
    rows carry exactly the static path's values and the inactive rows are
    exactly zero.
    """
    if not M.static_depth(d):
        client_p, server_p, local_p = SN.split_params(cfg, params, None)
        out = tpgf_grads_split(cfg, cfg, client_p, server_p, local_p,
                               batch, d, server_available=server_available)
        grads = SN.merge_params(cfg, out.g_client, out.g_server,
                                out.g_local, d)
        return TPGFOut(grads, out.loss_client, out.loss_server,
                       out.w_client, out.aux)
    d_s = cfg.split_stack_len - d
    client_p, server_p, local_p = SN.split_params(cfg, params, d)

    # ---- shared prefix forward with a single vjp (Algorithm 2, line 13)
    def prefix_fn(cp):
        full = SN.merge_params(cfg, cp, server_p, local_p)
        return M.prefix_apply(cfg, full, batch, d)

    (z, aux_prefix), vjp_prefix = jax.vjp(prefix_fn, client_p)

    # ---- Phase 1: local supervision
    def local_fn(lp, z_):
        full = SN.merge_params(cfg, client_p, server_p, lp)
        return M.local_loss(cfg, full, z_, batch)

    loss_client, (g_local, gz_client) = jax.value_and_grad(
        local_fn, argnums=(0, 1))(local_p, z)

    # ---- Phase 2: server supervision
    def server_fn(sp, z_):
        full = SN.merge_params(cfg, client_p, sp, local_p)
        return M.server_loss(cfg, full, z_, batch, d)

    loss_server, (g_server_params, gz_server) = jax.value_and_grad(
        server_fn, argnums=(0, 1))(server_p, z)

    # client backprop of each branch's dL/dz through the encoder
    (g_client_local,) = vjp_prefix((gz_client, jnp.zeros_like(aux_prefix)))
    (g_client_server,) = vjp_prefix((gz_server, jnp.zeros_like(aux_prefix)))

    # ---- Phase 3: clip + loss-weighted fusion (Eqs. 3-4)
    g_client_local, _ = clip_by_global_l2(g_client_local, cfg.tpgf_clip)
    w_c = tpgf_weight(loss_client, loss_server, d, d_s, cfg.tpgf_eps,
                      variant=cfg.tpgf_variant)
    g_client = fuse_gradients(g_client_local, g_client_server, w_c,
                              use_pallas=cfg.use_pallas)
    w_c, g_server_params, g_client = _fault_degrade(
        server_available, w_c, g_server_params, g_client, g_client_local)

    grads = SN.merge_params(cfg, g_client, g_server_params, g_local)
    return TPGFOut(grads, loss_client, loss_server, w_c, aux_prefix)


class TPGFSplitOut(NamedTuple):
    g_client: Dict[str, Any]     # sliced-client-aligned gradient tree
    g_server: Dict[str, Any]     # server-view gradient tree
    g_local: Dict[str, Any]      # phi_i gradient tree
    loss_client: jnp.ndarray
    loss_server: jnp.ndarray
    w_client: jnp.ndarray
    aux: jnp.ndarray


def tpgf_grads_split(cfg: ModelConfig, wcfg: ModelConfig, client_p, server_p,
                     local_p, batch, d, *,
                     server_available=None) -> TPGFSplitOut:
    """TPGF over an already-split (and possibly width-sliced) subnetwork.

    ``client_p`` is the ``split_params(cfg, params, d, width)`` client view
    and ``wcfg`` the matching ``supernet.width_cfg`` — the client forward
    runs entirely on the slice, so a narrow client never materializes (or
    pays FLOPs for) the pruned coordinates. The returned ``g_client`` is
    aligned with the slice; the caller scatters it back into the shared
    supernet with ``supernet.scatter_width`` / ``widen_width`` so
    aggregation stays mask-aware. Phases and the fault-tolerant degrade
    mirror :func:`tpgf_grads` exactly.

    When ``d`` is a jax scalar, both views must hold all ``L`` stack rows
    (``split_params(cfg, params, None, width)``): the forwards run the
    masked scans, inactive rows get exactly zero gradient, and one jit
    program serves every depth.
    """
    d_s = cfg.split_stack_len - d
    length = None if M.static_depth(d) else d

    # ---- shared prefix forward with a single vjp (Algorithm 2, line 13)
    def prefix_fn(cp):
        return M.client_apply(wcfg, cp, batch, length=length)

    (z, aux_prefix), vjp_prefix = jax.vjp(prefix_fn, client_p)

    # ---- Phase 1: local supervision (the local head is width-oblivious —
    # it reads the full-d_model smashed data)
    def local_fn(lp, z_):
        return M.local_loss(cfg, lp, z_, batch)

    loss_client, (g_local, gz_client) = jax.value_and_grad(
        local_fn, argnums=(0, 1))(local_p, z)

    # ---- Phase 2: server supervision (full-width suffix)
    def server_fn(sp, z_):
        return M.server_split_loss(cfg, sp, z_, batch, length=length)

    loss_server, (g_server_params, gz_server) = jax.value_and_grad(
        server_fn, argnums=(0, 1))(server_p, z)

    # client backprop of each branch's dL/dz through the encoder slice
    (g_client_local,) = vjp_prefix((gz_client, jnp.zeros_like(aux_prefix)))
    (g_client_server,) = vjp_prefix((gz_server, jnp.zeros_like(aux_prefix)))

    # ---- Phase 3: clip + loss-weighted fusion (Eqs. 3-4)
    g_client_local, _ = clip_by_global_l2(g_client_local, cfg.tpgf_clip)
    w_c = tpgf_weight(loss_client, loss_server, d, d_s, cfg.tpgf_eps,
                      variant=cfg.tpgf_variant)
    g_client = fuse_gradients(g_client_local, g_client_server, w_c,
                              use_pallas=cfg.use_pallas)
    w_c, g_server_params, g_client = _fault_degrade(
        server_available, w_c, g_server_params, g_client, g_client_local)
    return TPGFSplitOut(g_client, g_server_params, g_local,
                        loss_client, loss_server, w_c, aux_prefix)


# ------------------------------------------------------- cross-tier fusion

class TierUpdate(NamedTuple):
    """One width tier's contribution to :func:`fuse_tiers`.

    width  — host float in (0, 1]: the tier's width slice (1.0 = full);
    weight — fp32 scalar (device scalar fine): the tier's mass — Eq. 6-style
             summed inverse fused losses of its live clients. Weight 0 means
             the tier trained nobody this round and must fuse as a no-op;
    tree   — the tier's update tree living on its width slice (plan leaves
             hold only the kept channel prefix), or already full-width.
    """
    width: float
    weight: Any
    tree: Any


def fuse_tiers(cfg: ModelConfig, tiers, *, base=None,
               use_pallas: bool = False):
    """Cross-tier TPGF: ONE full-width update from per-tier width slices.

    Lift -> per-coordinate fuse -> single update: each tier's tree is
    zero-extended to full-width coordinates (``supernet.widen_width``, the
    ``widen(slice(t)) == mask(t)`` identity), then fused with
    per-coordinate denominators reusing ``aggregation.width_coord_masks``
    — the same membership law as Eq. (8)'s width-aware aggregation — so a
    coordinate pruned in some tiers is fused only over the tiers that
    actually trained it:

        fused[f] = sum_t ( w_t * m_t[f] / sum_u w_u * m_u[f] ) * x_t[f]

    The normalizer divides BEFORE the multiply: a coordinate held by
    exactly one tier gets that tier's value exactly (``w/w == 1.0`` in
    IEEE) and a zero-weight tier contributes an exact ``+/-0.0`` — the
    property suite in ``tests/test_tpgf_cross_tier.py`` pins both. Tiers
    are canonically sorted by width before accumulating, so the result is
    invariant (bit for bit) to the caller's tier ordering; equal-width
    tiers keep their given order (two-term float adds commute exactly).

    ``base=None`` fuses gradient-like trees: coordinates no tier holds
    come out zero. With ``base`` (delta mode, used for the shared server
    branch and its optimizer moments) the result is
    ``base + sum_t hw_t * (x_t - base)`` and un-held coordinates fall back
    to ``base`` through a where-guard, so an all-zero-weight cohort is a
    bit-exact no-op — the frozen-server invariant under fusion.

    ``use_pallas`` routes the full-width (scalar-weight) accumulation
    through the ``tpgf_fusion.tier_sum`` kernel; the per-coordinate slice
    path stays in jnp (the postscale is memory-bound either way).
    """
    from repro.core import aggregation as AGG

    if not tiers:
        raise ValueError("fuse_tiers needs at least one tier")
    tiers = sorted(tiers, key=lambda t: float(t.width))
    widths = [float(t.width) for t in tiers]
    wts = [jnp.asarray(t.weight, jnp.float32) for t in tiers]
    lifted = [SN.widen_width(cfg, t.tree, t.width) for t in tiers]

    tot = wts[0]
    for wt in wts[1:]:
        tot = tot + wt
    safe_tot = jnp.where(tot > 0, tot, 1.0)
    coord = any(wi < 1.0 for wi in widths)
    plan = SN.width_plan(cfg, 1.0)
    masks = AGG.width_coord_masks(cfg, widths) if coord else {}
    wvec = jnp.stack(wts)

    flat0, treedef = jax.tree_util.tree_flatten_with_path(lifted[0])
    flats = [jax.tree_util.tree_flatten_with_path(t)[0] for t in lifted]
    base_leaves = ([None] * len(flat0) if base is None
                   else jax.tree.leaves(base))
    out = []
    for i, (path, x0) in enumerate(flat0):
        name = SN._leaf_name(path)
        xs = [flat[i][1].astype(jnp.float32) for flat in flats]
        b = base_leaves[i]
        bf = None if b is None else b.astype(jnp.float32)
        if coord and name in masks:
            ax, F = plan[name]
            axis = x0.ndim + ax
            den = jnp.einsum("t,tf->f", wvec, masks[name])        # [F]
            sden = jnp.where(den > 0, den, 1.0)
            shape = [1] * x0.ndim
            shape[axis] = F
            held = (den > 0).reshape(shape)
            acc = None
            for wt, mt, xf in zip(wts, masks[name], xs):
                hw = (wt * mt / sden).reshape(shape)
                term = hw * (xf if bf is None else xf - bf)
                acc = term if acc is None else acc + term
        else:
            held = tot > 0
            hws = [jnp.where(held, wt / safe_tot, 0.0) for wt in wts]
            terms = xs if bf is None else [xf - bf for xf in xs]
            if use_pallas:
                from repro.kernels.tpgf_fusion.ops import tier_sum_leaf
                acc = tier_sum_leaf(terms, hws)
            else:
                acc = None
                for hw, term in zip(hws, terms):
                    acc = hw * term if acc is None else acc + hw * term
        if bf is None:
            fused = jnp.where(held, acc, jnp.zeros((), jnp.float32))
        else:
            fused = jnp.where(held, bf + acc, bf)
        out.append(fused.astype(x0.dtype if b is None else b.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def local_only_grads(cfg: ModelConfig, params, batch, d: int):
    """Pure fallback-mode step (server unreachable) — Algorithm 3 else-branch.

    Returns (grads, loss_client): encoder + local head trained from the
    client classifier alone; server parameters receive zero gradient.
    """
    client_p, server_p, local_p = SN.split_params(cfg, params, d)

    def loss_fn(cp, lp):
        full = SN.merge_params(cfg, cp, server_p, lp)
        z, _ = M.prefix_apply(cfg, full, batch, d)
        return M.local_loss(cfg, full, z, batch)

    loss, (g_client, g_local) = jax.value_and_grad(
        loss_fn, argnums=(0, 1))(client_p, local_p)
    g_client, _ = clip_by_global_l2(g_client, cfg.tpgf_clip)
    zeros_server = jax.tree.map(jnp.zeros_like, server_p)
    grads = SN.merge_params(cfg, g_client, zeros_server, g_local)
    return grads, loss

"""Weight-sharing super-network: parameter views for the client/server split.

The super-network is the stacked-layer parameter tree from
``repro.models.model.init_params``. A client subnetwork of depth ``d`` is a
*contiguous prefix* of the split stack (paper §II-A); here that is a slice of
the leading ``L`` axis plus the input-side parameters (embedding / patch /
frame projections), which every client holds (they are "layer 0" of the
prefix in the paper's sense).

Beside depth, the supernet slices *width* (paper §II-A, Fig. 2): a width
tier ``w in (0, 1]`` keeps the leading-channel prefix of every layer's MLP
hidden dim and attention heads (whole GQA groups, so kept query heads never
read a pruned KV head). ``width_cfg`` derives the sliced ``ModelConfig``
(hashable — it doubles as the jit static key), ``width_plan`` names the
sliced (axis, keep) per leaf, and ``slice_width`` / ``mask_width`` /
``widen_width`` / ``scatter_width`` are the four views the slice-parity
contract in ``tests/test_supernet_width.py`` pins:

  slice  — take the kept prefix (the client's download);
  mask   — zero the pruned coordinates in a full tree (slice-then-forward
           == forward-then-mask, because pruned head/hidden outputs are
           killed by the zeroed ``wo`` / ``w_down`` rows);
  widen  — zero-embed a sliced tree back to full shape
           (``widen(slice(t)) == mask(t)`` identically);
  scatter— write a sliced tree into a full one, touching ONLY the kept
           coordinates (gradient scatter-back into the shared supernet).

The residual stream (``d_model``, the smashed data) stays full-width at
every tier, so the server branch and the fault-tolerant local head are
width-oblivious.

``split_params`` / ``merge_params`` give disjoint client | server | local
views so TPGF can compute per-branch gradients without masking tricks.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

# input-side parameter names that always live on the client
_CLIENT_INPUT_KEYS = ("embed", "vision_proj", "patch_embed", "patch_bias",
                      "pos_embed", "frame_proj")
# the fault-tolerant classifier phi_i — never aggregated (paper §II-D)
_LOCAL_KEYS = ("local_head", "local_head_bias")


def split_stack_name(cfg: ModelConfig) -> str:
    return "enc_layers" if cfg.is_encdec else "layers"


def prefix(stack, d: int):
    return jax.tree.map(lambda x: x[:d], stack)


def suffix(stack, d: int):
    return jax.tree.map(lambda x: x[d:], stack)


# --------------------------------------------------------------- width views

def width_cfg(cfg: ModelConfig, width: float) -> ModelConfig:
    """The sliced ``ModelConfig`` for a width tier ``w in (0, 1]``.

    Heads slice by whole GQA groups — ``Kw = max(1, round(w * n_kv_heads))``
    KV heads, ``Hw = (n_heads // n_kv_heads) * Kw`` query heads — so a kept
    query head always reads a kept KV head. ``head_dim`` is pinned
    explicitly (``resolved_head_dim`` would recompute it from the sliced
    ``n_heads``). The returned config is frozen/hashable, so it serves both
    as the apply-time dimension source and as part of the kernel's jit
    static key.
    """
    if width >= 1.0:
        return cfg
    hd = cfg.resolved_head_dim
    group = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    kv = max(1, int(round(width * cfg.n_kv_heads)))
    dff = max(1, int(round(width * cfg.d_ff)))
    return cfg.replace(n_heads=group * kv, n_kv_heads=kv, d_ff=dff,
                       head_dim=hd)


def width_plan(cfg: ModelConfig, width: float) -> Dict[str, Tuple[int, int]]:
    """leaf-name -> (axis, keep): the sliced axis and kept prefix length.

    Axes are negative so one plan covers ``[...]``, ``[L, ...]`` and
    ``[N, L, ...]`` leaves (and MoE ``[E, dm, dff]`` expert weights). Names
    absent from the plan — norms, ``b_down``, branch scales, SSM/router,
    input-side and head parameters — stay full-width: they live on the
    ``d_model`` residual stream, which never slices.
    """
    wcfg = width_cfg(cfg, width)
    hd = cfg.resolved_head_dim
    qh = wcfg.n_heads * hd
    kvh = wcfg.n_kv_heads * hd
    dff = wcfg.d_ff
    return {
        "wq": (-1, qh), "bq": (-1, qh),
        "wk": (-1, kvh), "wv": (-1, kvh), "bk": (-1, kvh), "bv": (-1, kvh),
        "wo": (-2, qh),
        "w_gate": (-1, dff), "w_up": (-1, dff), "b_up": (-1, dff),
        "w_down": (-2, dff),
    }


def _leaf_name(path) -> Any:
    k = path[-1]
    return getattr(k, "key", getattr(k, "idx", None))


def _map_width(cfg: ModelConfig, tree, width: float, fn):
    """Apply ``fn(leaf, axis, keep)`` to every plan leaf, identity elsewhere."""
    plan = width_plan(cfg, width)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [fn(leaf, *plan[_leaf_name(path)])
           if _leaf_name(path) in plan else leaf
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def slice_width(cfg: ModelConfig, tree, width: float):
    """Kept-prefix view of a (full-width) parameter/gradient tree."""
    if width >= 1.0:
        return tree

    def take(x, ax, keep):
        return jax.lax.slice_in_dim(x, 0, keep, axis=x.ndim + ax)

    return _map_width(cfg, tree, width, take)


def mask_width(cfg: ModelConfig, tree, width: float):
    """Zero the pruned coordinates of a full-width tree (NaN-safe where)."""
    if width >= 1.0:
        return tree

    def mask(x, ax, keep):
        axis = x.ndim + ax
        kept = jnp.arange(x.shape[axis]) < keep
        kept = kept.reshape((-1,) + (1,) * (x.ndim - 1 - axis))
        return jnp.where(kept, x, jnp.zeros((), x.dtype))

    return _map_width(cfg, tree, width, mask)


def widen_width(cfg: ModelConfig, tree, width: float, *, full_cfg=None):
    """Zero-embed a sliced tree back to full width (the scatter identity
    ``widen(slice(t)) == mask(t)``). ``full_cfg`` defaults to ``cfg``."""
    if width >= 1.0:
        return tree
    full = width_plan(full_cfg or cfg, 1.0)
    plan = width_plan(full_cfg or cfg, width)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _leaf_name(path)
        if name in plan:
            ax, keep = plan[name]
            axis = leaf.ndim + ax
            pad = [(0, 0)] * leaf.ndim
            pad[axis] = (0, full[name][1] - keep)
            leaf = jnp.pad(leaf, pad)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def scatter_width(cfg: ModelConfig, full_tree, sliced_tree, width: float):
    """Write a sliced tree into a full-width one, touching ONLY the kept
    coordinates (plan leaves: kept prefix; non-plan leaves are fully held
    by the client, so they are replaced whole)."""
    if width >= 1.0:
        return sliced_tree
    plan = width_plan(cfg, width)
    flat_f, treedef = jax.tree_util.tree_flatten_with_path(full_tree)
    flat_s = jax.tree_util.tree_flatten_with_path(sliced_tree)[0]
    out = []
    for (path, f), (_, s) in zip(flat_f, flat_s):
        name = _leaf_name(path)
        if name in plan:
            ax, keep = plan[name]
            axis = f.ndim + ax
            idx = tuple(slice(0, keep) if i == axis else slice(None)
                        for i in range(f.ndim))
            out.append(f.at[idx].set(s.astype(f.dtype)))
        else:
            out.append(s.astype(f.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def width_keep_sizes(cfg: ModelConfig, width: float) -> Dict[str, int]:
    """leaf-name -> kept prefix length (host-side helper for the
    per-coordinate aggregation denominators in ``core.aggregation``)."""
    return {k: keep for k, (_, keep) in width_plan(cfg, width).items()}


def split_params(cfg: ModelConfig, params: Params, d=None,
                 width: float = 1.0) -> Tuple[Params, Params, Params]:
    """-> (client theta_i, server theta_s, local phi_i), disjoint.

    A static (Python int) ``d`` slices the depth window at trace time:
    the client stack holds rows ``[:d]`` and the server stack rows
    ``[d:]``. ``d=None`` builds the *runtime-depth* views instead — BOTH
    stacks keep all ``L`` rows (width still slices the client's channel
    dims) and the kernels pass ``d`` as a jax scalar to the masked-scan
    apply functions, so one jit program serves every depth tier.

    ``width < 1`` width-slices the CLIENT stack only: the smashed data is
    full ``d_model``, so the server suffix and the local head stay
    full-width regardless of the client's tier.
    """
    sname = split_stack_name(cfg)
    client: Params = {}
    server: Params = {}
    local: Params = {}
    for k, v in params.items():
        if k in _LOCAL_KEYS:
            local[k] = v
        elif k == sname:
            cstack = v if d is None else prefix(v, d)
            if width < 1.0:
                cstack = slice_width(cfg, cstack, width)
            client[k] = cstack
            server[k] = v if d is None else suffix(v, d)
        elif k in _CLIENT_INPUT_KEYS and not (cfg.is_encdec and k == "embed"):
            # NB: the enc-dec decoder embedding is server-side (the split
            # stack is the encoder), so whisper's "embed" stays on the server
            client[k] = v
        else:
            server[k] = v
    return client, server, local


def merge_params(cfg: ModelConfig, client: Params, server: Params,
                 local: Params, d=None) -> Params:
    """Inverse of ``split_params``. With the static views (``d=None``
    here), the two depth slices concatenate back. With full-``L``
    runtime views, pass the jax scalar ``d`` and each stack row selects
    client (``row < d``) or server (``row >= d``) — the same rows the
    masked scans actually trained."""
    sname = split_stack_name(cfg)
    out: Params = {}
    for k, v in client.items():
        if k == sname:
            if d is None:
                out[k] = jax.tree.map(
                    lambda a, b: jax.numpy.concatenate([a, b], axis=0),
                    v, server[k])
            else:
                out[k] = jax.tree.map(
                    lambda a, b: depth_select(a, b, d, keep="prefix"),
                    v, server[k])
        else:
            out[k] = v
    for k, v in server.items():
        if k not in out:
            out[k] = v
    out.update(local)
    return out


def depth_select(new, old, d, *, keep: str, axis: int = 0):
    """Row-select along a stacked-layer axis: rows ``< d`` come from
    ``new`` when ``keep="prefix"`` (else from ``old``), and vice versa
    for the suffix. The kernels use this to freeze the out-of-window rows
    of full-``L`` runtime-depth stacks — reverting an optimizer update on
    a frozen row to its carried value is bit-equal to never updating it,
    because every fleet optimizer is elementwise."""
    rows = jnp.arange(new.shape[axis]).reshape(
        (1,) * axis + (-1,) + (1,) * (new.ndim - 1 - axis))
    in_prefix = rows < d
    take_new = in_prefix if keep == "prefix" else ~in_prefix
    return jnp.where(take_new, new, old)


def depth_freeze(cfg: ModelConfig, new, old, d, *, keep: str,
                 axis: int = 0):
    """Revert the out-of-depth-window rows of the split STACK inside a
    params-shaped tree (client/server view) or an optimizer-state dict.

    Only the ``split_stack_name`` subtree is row-selected (via
    :func:`depth_select`); non-stack leaves — input-side parameters,
    heads, the enc-dec decoder, optimizer bookkeeping like AdamW's ``t``
    — pass through from ``new`` untouched. For an optimizer state, every
    moment entry (a dict mirroring the params tree) gets the same
    treatment; stateless ``()`` states pass through whole.
    """
    sname = split_stack_name(cfg)

    def fz(ntree, otree):
        out = dict(ntree)
        out[sname] = jax.tree.map(
            lambda a, b: depth_select(a, b, d, keep=keep, axis=axis),
            ntree[sname], otree[sname])
        return out

    if isinstance(new, dict) and sname in new:
        return fz(new, old)
    if isinstance(new, dict):   # optimizer state: moment entries only
        return {k: fz(v, old[k])
                if isinstance(v, dict) and sname in v else v
                for k, v in new.items()}
    return new


def client_param_bytes(cfg: ModelConfig, params: Params, d: int,
                       width: float = 1.0) -> int:
    """Size of a (depth, width) subnetwork — the per-round download cost."""
    client, _, local = split_params(cfg, params, d, width)
    leaves = jax.tree.leaves(client) + jax.tree.leaves(local)
    return sum(int(x.size) * x.dtype.itemsize for x in leaves)


def smashed_bytes(z) -> int:
    return int(z.size) * z.dtype.itemsize

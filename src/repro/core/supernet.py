"""Weight-sharing super-network: parameter views for the client/server split.

The super-network is the stacked-layer parameter tree from
``repro.models.model.init_params``. A client subnetwork of depth ``d`` is a
*contiguous prefix* of the split stack (paper §II-A); here that is a slice of
the leading ``L`` axis plus the input-side parameters (embedding / patch /
frame projections), which every client holds (they are "layer 0" of the
prefix in the paper's sense).

``split_params`` / ``merge_params`` give disjoint client | server | local
views so TPGF can compute per-branch gradients without masking tricks.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

# input-side parameter names that always live on the client
_CLIENT_INPUT_KEYS = ("embed", "vision_proj", "patch_embed", "patch_bias",
                      "pos_embed", "frame_proj")
# the fault-tolerant classifier phi_i — never aggregated (paper §II-D)
_LOCAL_KEYS = ("local_head", "local_head_bias")


def split_stack_name(cfg: ModelConfig) -> str:
    return "enc_layers" if cfg.is_encdec else "layers"


def prefix(stack, d: int):
    return jax.tree.map(lambda x: x[:d], stack)


def suffix(stack, d: int):
    return jax.tree.map(lambda x: x[d:], stack)


def split_params(cfg: ModelConfig, params: Params, d: int
                 ) -> Tuple[Params, Params, Params]:
    """-> (client theta_i, server theta_s, local phi_i), disjoint."""
    sname = split_stack_name(cfg)
    client: Params = {}
    server: Params = {}
    local: Params = {}
    for k, v in params.items():
        if k in _LOCAL_KEYS:
            local[k] = v
        elif k == sname:
            client[k] = prefix(v, d)
            server[k] = suffix(v, d)
        elif k in _CLIENT_INPUT_KEYS and not (cfg.is_encdec and k == "embed"):
            # NB: the enc-dec decoder embedding is server-side (the split
            # stack is the encoder), so whisper's "embed" stays on the server
            client[k] = v
        else:
            server[k] = v
    return client, server, local


def merge_params(cfg: ModelConfig, client: Params, server: Params,
                 local: Params) -> Params:
    sname = split_stack_name(cfg)
    out: Params = {}
    for k, v in client.items():
        if k == sname:
            out[k] = jax.tree.map(
                lambda a, b: jax.numpy.concatenate([a, b], axis=0),
                v, server[k])
        else:
            out[k] = v
    for k, v in server.items():
        if k not in out:
            out[k] = v
    out.update(local)
    return out


def client_param_bytes(cfg: ModelConfig, params: Params, d: int) -> int:
    """Size of a depth-d subnetwork — the per-round model download cost."""
    client, _, local = split_params(cfg, params, d)
    leaves = jax.tree.leaves(client) + jax.tree.leaves(local)
    return sum(int(x.size) * x.dtype.itemsize for x in leaves)


def smashed_bytes(z) -> int:
    return int(z.size) * z.dtype.itemsize

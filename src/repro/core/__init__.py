from repro.core import supernet, allocation, tpgf, aggregation, fault  # noqa: F401

"""Collaborative client-server model aggregation — paper §II-D.

Client weighting (Eq. 6):
    w_i = d_i / sum_j d_j  *  (L_i + eps)^-1 / sum_j (L_j + eps)^-1
with L_i the client loss, or the TPGF-fused loss when the client had server
supervision that round.

Layer-aligned averaging with server consistency (Eq. 7/8, closed form):
    theta_bar^l = (sum_{i has l} w_i theta_i^l + lambda theta_s^l)
                  / (sum_{i has l} w_i + lambda)

Because the super-network is a stacked tree, clients are one more leading
axis: stacked client params are [N, L, ...] and presence is a [N, L] mask —
the whole aggregation is a handful of einsums (and the Pallas
``layer_aggregate`` kernel mirrors the hot leaf case).

Sharded-stack contract: under ``Engine(mesh=...)`` the stacked client axis
arrives sharded over the fleet mesh (``launch.sharding.fleet_pspecs`` —
the same layout the shard-mapped cohort kernels scatter into), ``w`` and
``mask`` ride the same [N] axis, and every client-axis contraction here
(the einsum numerators, the weight normalizers) reduces it away — XLA
emits the cross-shard all-reduce and the new global params come out
replicated, so this module stays the ONE place reductions cross the
client axis and the one-host-sync-per-round contract survives sharding
untouched.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import supernet as SN


def client_weights(depths, losses, eps: float = 1e-8, mask=None):
    """Eq. (6). depths [N] int, losses [N] (client or fused). -> [N] fp32.

    ``mask`` ([N] bool) restricts the weighting to the clients that actually
    trained this round: masked-out entries get weight 0 and contribute
    nothing to either normalizer. This is how the device-resident engine
    consumes full-fleet stacked buffers directly — no host-side filtering.
    """
    depths = jnp.asarray(depths, jnp.float32)
    losses = jnp.asarray(losses, jnp.float32)
    if mask is not None:
        mask = jnp.asarray(mask)
        depths = jnp.where(mask, depths, 0.0)
        inv = jnp.where(mask, 1.0 / (losses + eps), 0.0)
    else:
        inv = 1.0 / (losses + eps)
    depth_term = depths / jnp.sum(depths)
    loss_term = inv / jnp.sum(inv)
    return depth_term * loss_term


def presence_mask(depths, n_layers: int):
    """[N, L] bool: client i holds layer l iff l < d_i."""
    depths = jnp.asarray(depths)
    return jnp.arange(n_layers)[None, :] < depths[:, None]


def _agg_leaf(client_leaf, server_leaf, w, pres, lam):
    """client_leaf [N, L, ...] or [N, ...]; server_leaf [L, ...] or [...]."""
    cf = client_leaf.astype(jnp.float32)
    sf = server_leaf.astype(jnp.float32)
    if client_leaf.ndim == server_leaf.ndim + 1 and pres is not None \
            and client_leaf.shape[1] == pres.shape[1]:
        ww = w[:, None] * pres.astype(jnp.float32)          # [N, L]
        num = jnp.einsum("nl,nl...->l...", ww, cf)
        den = jnp.sum(ww, axis=0)  # [L]  # fleetlint: disable=FL002 — ww zeroes masked clients upstream (depth_loss_weights mask)
        den = den.reshape((-1,) + (1,) * (cf.ndim - 2))
        out = (num + lam * sf) / (den + lam)
    else:
        num = jnp.einsum("n,n...->...", w, cf)
        out = (num + lam * sf) / (jnp.sum(w) + lam)
    return out.astype(server_leaf.dtype)


def width_coord_masks(cfg: ModelConfig, widths):
    """leaf-name -> [T, F] fp32 channel-keep masks over the width plan.

    Row ``t`` is the indicator of the coordinates a width-``widths[t]``
    holder keeps on that leaf's sliced axis (kept channel prefix, whole
    GQA head groups — ``supernet.width_keep_sizes``). This is THE
    per-coordinate membership law: ``_agg_stacked_width`` contracts it
    against per-client weights for the Eq. (8) denominators, and
    ``tpgf.fuse_tiers`` against per-tier masses for cross-tier fusion —
    both paths share one definition of "who holds coordinate f".
    ``widths`` are host floats (tiers or per-client), not traced.
    """
    plan = SN.width_plan(cfg, 1.0)
    keeps = {name: np.array([SN.width_keep_sizes(cfg, float(wi))[name]
                             for wi in widths])
             for name in plan}
    return {name: (jnp.arange(full_keep)[None, :]
                   < jnp.asarray(keeps[name])[:, None]).astype(jnp.float32)
            for name, (_, full_keep) in plan.items()}


def _agg_stacked_width(cfg: ModelConfig, leaf_tree, server_tree, w, pres,
                       lam, widths):
    """Width-aware Eq. (8) over the split stack: per-COORDINATE denominators.

    A width-w client's stacked row is zero beyond its kept channel prefix
    (``supernet.widen_width`` pads zeros), so the numerator is already
    correct; the denominator must exclude that client's weight at the
    coordinates it never held, or pruned channels would be dragged toward
    zero. Coordinates held by no client fall back to the server value
    (den=0 -> (0 + lam*sf)/(0 + lam) = sf).
    """
    plan = SN.width_plan(cfg, 1.0)
    chans = width_coord_masks(cfg, widths)
    flat_c, treedef = jax.tree_util.tree_flatten_with_path(leaf_tree)
    flat_s = jax.tree_util.tree_flatten_with_path(server_tree)[0]
    ww = w[:, None] * pres.astype(jnp.float32)                  # [N, L]
    out = []
    for (path, c), (_, s) in zip(flat_c, flat_s):
        name = SN._leaf_name(path)
        if name not in plan:
            out.append(_agg_leaf(c, s, w, pres, lam))
            continue
        ax, _ = plan[name]
        axis = s.ndim + ax                 # sliced axis in the [L, ...] leaf
        F = s.shape[axis]
        cf = c.astype(jnp.float32)
        sf = s.astype(jnp.float32)
        num = jnp.einsum("nl,nl...->l...", ww, cf)
        den = jnp.einsum("nl,nf->lf", ww, chans[name])
        shape = [1] * s.ndim
        shape[0] = s.shape[0]
        shape[axis] = F
        den = den.reshape(shape)
        out.append(((num + lam * sf) / (den + lam)).astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def aggregate(cfg: ModelConfig, global_params: Dict[str, Any],
              client_stacks: Dict[str, Any], depths, losses,
              *, lam: float = None, use_pallas: bool = False, mask=None,
              widths=None):
    """Eq. (6)+(8) over the aggregation-eligible (encoder) parameters.

    global_params: the server's current full tree (theta_s source AND the
        carrier of non-aggregated params: server suffix, heads).
    client_stacks: client-stacked *client trees* — input-side leaves
        [N, ...], split-stack leaves [N, L_full, ...] zero-padded beyond
        each client's depth. Produced either by ``stack_client_trees`` over
        host lists (legacy) or directly by the engine's device-resident
        full-fleet workspace, in which case ``mask`` marks the rows that
        trained this round (untrained rows get zero weight).
    """
    w = client_weights(depths, losses, cfg.tpgf_eps, mask=mask)
    return aggregate_weighted(cfg, global_params, client_stacks, depths, w,
                              lam=lam, use_pallas=use_pallas,
                              widths=widths), w


def aggregate_weighted(cfg: ModelConfig, global_params: Dict[str, Any],
                       client_stacks: Dict[str, Any], depths, w,
                       *, lam: float = None, use_pallas: bool = False,
                       mask=None, widths=None):
    """Eq. (8)-form layer-aligned averaging with externally supplied client
    weights ``w`` [N] — uniform FedAvg (SFL), depth-weighted (DFL), or any
    scenario-specific weighting a strategy wants. ``aggregate`` is the
    special case where ``w`` comes from Eq. (6). With a validity ``mask``,
    masked-out rows (clients that did not train; their stacked rows are
    stale or zero) are forced to weight 0.

    ``widths`` ([N] host floats, width tier per client) switches the split
    stack to per-coordinate denominators (``_agg_stacked_width``) — only
    when some tier is < 1, so homogeneous full-width fleets take the exact
    legacy einsum path."""
    lam = cfg.agg_lambda if lam is None else lam
    if mask is not None:
        w = jnp.where(jnp.asarray(mask), jnp.asarray(w, jnp.float32), 0.0)
    pres = presence_mask(depths, cfg.split_stack_len)
    sname = SN.split_stack_name(cfg)
    widths_np = None if widths is None else np.asarray(widths, np.float64)
    width_active = widths_np is not None and bool((widths_np < 1.0).any())

    def agg_stacked(c, s):
        if use_pallas and c.ndim >= 3:
            from repro.kernels.layer_aggregate.ops import aggregate_leaf
            ww = w[:, None] * pres.astype(jnp.float32)
            return aggregate_leaf(c, ww, s, lam)
        return _agg_leaf(c, s, w, pres, lam)

    new_params = dict(global_params)
    for key, leaf_tree in client_stacks.items():
        if key == sname:
            if width_active:
                new_params[key] = _agg_stacked_width(
                    cfg, leaf_tree, global_params[key], w, pres, lam,
                    widths_np)
            else:
                new_params[key] = jax.tree.map(agg_stacked, leaf_tree,
                                               global_params[key])
        else:
            new_params[key] = jax.tree.map(
                lambda c, s: _agg_leaf(c, s, w, None, lam),
                leaf_tree, global_params[key])
    return new_params


def stack_client_trees(cfg: ModelConfig, client_trees: Sequence[Dict],
                       depths) -> Dict[str, Any]:
    """Stack per-client client-param trees into [N, ...] / [N, L_full, ...].

    Legacy host-list entry point: the engine's round loop now accumulates
    the same layout directly on device (``strategies.base.fleet_workspace``
    + a validity mask); this helper remains for tests and external callers
    holding per-client trees.

    Each client tree's split stack has its own depth d_i; rows are placed at
    [0:d_i] and the rest zero-padded (they are masked out by presence).
    """
    sname = SN.split_stack_name(cfg)
    Lfull = cfg.split_stack_len
    out: Dict[str, Any] = {}
    keys = client_trees[0].keys()
    for key in keys:
        if key == sname:
            def pad(leaf, d):
                pads = [(0, Lfull - d)] + [(0, 0)] * (leaf.ndim - 1)
                return jnp.pad(leaf, pads)
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[jax.tree.map(lambda x, dd=d: pad(x, dd), t[key])
                  for t, d in zip(client_trees, depths)])
        else:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[t[key] for t in client_trees])
        out[key] = stacked
    return out

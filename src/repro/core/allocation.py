"""Resource-aware subnetwork allocation — paper Eq. (1) / Algorithm 1.

    d_i = min( floor(alpha * m_i)
             + floor(beta * (lat_max - lat_i) / (lat_max - lat_min + eps)),
             L - 1 ),   d_i >= 1

alpha = 0.5 layers/GB, beta = 4 (paper defaults; interpretable heuristics,
not tuned hyper-parameters). Profiles are reported once at initialization;
no runtime re-profiling (paper §II-A).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    mem_gb: float   # memory capacity m_i
    lat_ms: float   # round-trip smashed-data latency lat_i


def allocate_depths(mem_gb, lat_ms, n_layers: int, *, alpha: float = 0.5,
                    beta: float = 4.0, eps: float = 1e-8):
    """Vectorized Eq. (1). mem_gb, lat_ms: arrays [N]. Returns int32 [N]."""
    mem_gb = jnp.asarray(mem_gb, jnp.float32)
    lat_ms = jnp.asarray(lat_ms, jnp.float32)
    lat_min = jnp.min(lat_ms)
    lat_max = jnp.max(lat_ms)
    mem_term = jnp.floor(alpha * mem_gb)
    lat_term = jnp.floor(beta * (lat_max - lat_ms)
                         / (lat_max - lat_min + eps))
    d = jnp.minimum(mem_term + lat_term, n_layers - 1)
    return jnp.maximum(d, 1).astype(jnp.int32)


def sample_profiles(n_clients: int, rng: np.random.Generator,
                    *, mem_range=(2.0, 16.0), lat_range=(20.0, 200.0)):
    """The paper's heterogeneity simulator: mem ~ U[2,16] GB,
    lat ~ U[20,200] ms (§III-A)."""
    mem = rng.uniform(*mem_range, size=n_clients)
    lat = rng.uniform(*lat_range, size=n_clients)
    return [ClientProfile(float(m), float(l)) for m, l in zip(mem, lat)]


def allocate_for_profiles(profiles, n_layers: int, *, alpha: float = 0.5,
                          beta: float = 4.0, eps: float = 1e-8):
    mem = np.array([p.mem_gb for p in profiles])
    lat = np.array([p.lat_ms for p in profiles])
    return np.asarray(
        allocate_depths(mem, lat, n_layers, alpha=alpha, beta=beta, eps=eps))


# --------------------------------------------------- HASFL-style co-tuning

def estimate_step_time_s(d, b, mem_gb, lat_ms, client_params_by_depth,
                         tokens_per_sample: int, bytes_per_sample: int, *,
                         gflops_per_mem: float = 1.25,
                         bandwidth_mb_s: float = 20.0):
    """Per-local-step wall time of a depth-``d`` / batch-``b`` client under
    the linear device model of ``repro.federated.metrics.DeviceModel``:
    6ND training FLOPs on the client prefix, plus the smashed-activation
    round trip (2 messages). Vectorizes over any broadcastable d/b/mem/lat."""
    d = np.asarray(d)
    flops = 6.0 * client_params_by_depth[d] * tokens_per_sample \
        * np.asarray(b, float)
    compute = flops / (gflops_per_mem * np.asarray(mem_gb, float) * 1e9)
    comm = (2.0 * bytes_per_sample * np.asarray(b, float)
            / (bandwidth_mb_s * 1024 * 1024)
            + 2.0 * np.asarray(lat_ms, float) / 1e3)
    return compute + comm


def allocate_widths(mem_gb, tiers, *, mem_range=(2.0, 16.0)):
    """Map client memory budgets onto a supernet width ladder.

    ``tiers`` is the ordered width ladder, e.g. ``(0.5, 0.75, 1.0)``. Each
    client's budget is placed proportionally within ``mem_range`` (the
    paper's §III-A profile range) and snapped to a tier: the smallest
    devices get the narrowest slice, the largest get the full supernet.
    Returns float64 [N] — the ``fleet.widths`` layout.
    """
    tiers = sorted(float(t) for t in tiers)
    assert tiers and all(0.0 < t <= 1.0 for t in tiers), \
        f"width tiers must be in (0, 1]: {tiers}"
    mem = np.asarray(mem_gb, np.float64)
    lo, hi = float(mem_range[0]), float(mem_range[1])
    frac = np.clip((mem - lo) / max(hi - lo, 1e-9), 0.0, 1.0)
    idx = np.minimum((frac * len(tiers)).astype(int), len(tiers) - 1)
    return np.asarray(tiers, np.float64)[idx]


def co_tune(capacity, mem_gb, lat_ms, client_params_by_depth,
            tokens_per_sample: int, bytes_per_sample: int, *,
            batch_choices=(4, 8, 16, 32), base_batch: int = 16,
            time_budget_factor: float = 1.0,
            gflops_per_mem: float = 1.25, bandwidth_mb_s: float = 20.0,
            width_tiers=None):
    """HASFL-style joint split-depth / batch-size tuning (Lin et al.).

    Per client, pick the (d, b) pair that maximizes the local batch size —
    and, at that batch size, the split depth — subject to the client's
    estimated per-step time staying within the round deadline ``T``. The
    deadline is ``time_budget_factor`` x the fleet-median step time at
    (Eq.1 capacity, ``base_batch``), so faster devices trade their slack
    for larger batches while stragglers shed depth and batch instead of
    stalling the synchronous round barrier.

    ``capacity`` is the Eq.1 memory bound: assignments never exceed it, and
    the floor (d=1, min batch) is always feasible, so every client gets a
    valid pair. ``client_params_by_depth[d]`` maps a depth to the client
    prefix's trainable-parameter count. Returns ``(depths, batches)``
    int arrays [N].

    With ``width_tiers`` (an ordered supernet width ladder) the solve is
    joint over (depth, batch, width): each client's chosen (d, b) pair is
    re-checked against the deadline with its prefix cost scaled by each
    tier (a width-w slice trains ~w of the prefix parameters), and the
    WIDEST tier that still fits wins — the narrowest tier is the
    always-feasible floor. Returns ``(depths, batches, widths)``.
    """
    capacity = np.asarray(capacity, int)
    mem_gb = np.asarray(mem_gb, float)
    lat_ms = np.asarray(lat_ms, float)
    choices = sorted(set(int(b) for b in batch_choices))
    assert choices, "need at least one batch choice"
    est = lambda d, b, i, w=1.0: estimate_step_time_s(
        d, b, mem_gb[i], lat_ms[i],
        np.asarray(client_params_by_depth, float) * w,
        tokens_per_sample, bytes_per_sample,
        gflops_per_mem=gflops_per_mem, bandwidth_mb_s=bandwidth_mb_s)
    n = len(capacity)
    deadline = time_budget_factor * float(np.median(
        [est(capacity[i], base_batch, i) for i in range(n)]))
    depths = np.empty(n, np.int32)
    batches = np.empty(n, np.int32)
    for i in range(n):
        depths[i], batches[i] = 1, choices[0]      # always-feasible floor
        done = False
        for b in reversed(choices):                # largest batch first...
            for d in range(int(capacity[i]), 0, -1):   # ...then deepest split
                if est(d, b, i) <= deadline:
                    depths[i], batches[i] = d, b
                    done = True
                    break
            if done:
                break
    if width_tiers is None:
        return depths, batches
    tiers = sorted(float(t) for t in width_tiers)
    assert tiers and all(0.0 < t <= 1.0 for t in tiers), \
        f"width tiers must be in (0, 1]: {tiers}"
    widths = np.full(n, tiers[0], np.float64)      # narrowest = feasible floor
    for i in range(n):
        for w in reversed(tiers):                  # widest tier that fits
            if est(depths[i], batches[i], i, w) <= deadline:
                widths[i] = w
                break
    return depths, batches, widths

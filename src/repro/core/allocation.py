"""Resource-aware subnetwork allocation — paper Eq. (1) / Algorithm 1.

    d_i = min( floor(alpha * m_i)
             + floor(beta * (lat_max - lat_i) / (lat_max - lat_min + eps)),
             L - 1 ),   d_i >= 1

alpha = 0.5 layers/GB, beta = 4 (paper defaults; interpretable heuristics,
not tuned hyper-parameters). Profiles are reported once at initialization;
no runtime re-profiling (paper §II-A).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    mem_gb: float   # memory capacity m_i
    lat_ms: float   # round-trip smashed-data latency lat_i


def allocate_depths(mem_gb, lat_ms, n_layers: int, *, alpha: float = 0.5,
                    beta: float = 4.0, eps: float = 1e-8):
    """Vectorized Eq. (1). mem_gb, lat_ms: arrays [N]. Returns int32 [N]."""
    mem_gb = jnp.asarray(mem_gb, jnp.float32)
    lat_ms = jnp.asarray(lat_ms, jnp.float32)
    lat_min = jnp.min(lat_ms)
    lat_max = jnp.max(lat_ms)
    mem_term = jnp.floor(alpha * mem_gb)
    lat_term = jnp.floor(beta * (lat_max - lat_ms)
                         / (lat_max - lat_min + eps))
    d = jnp.minimum(mem_term + lat_term, n_layers - 1)
    return jnp.maximum(d, 1).astype(jnp.int32)


def sample_profiles(n_clients: int, rng: np.random.Generator,
                    *, mem_range=(2.0, 16.0), lat_range=(20.0, 200.0)):
    """The paper's heterogeneity simulator: mem ~ U[2,16] GB,
    lat ~ U[20,200] ms (§III-A)."""
    mem = rng.uniform(*mem_range, size=n_clients)
    lat = rng.uniform(*lat_range, size=n_clients)
    return [ClientProfile(float(m), float(l)) for m, l in zip(mem, lat)]


def allocate_for_profiles(profiles, n_layers: int, *, alpha: float = 0.5,
                          beta: float = 4.0, eps: float = 1e-8):
    mem = np.array([p.mem_gb for p in profiles])
    lat = np.array([p.lat_ms for p in profiles])
    return np.asarray(
        allocate_depths(mem, lat, n_layers, alpha=alpha, beta=beta, eps=eps))

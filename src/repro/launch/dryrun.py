import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this lowers the real production step (train_step with
TPGF for ``train_*``, prefill_step for ``prefill_*``, serve_step for
``decode_*`` / ``long_*``) against ShapeDtypeStruct stand-ins (NO
allocation), compiles under the production mesh, and records:
  - memory_analysis (bytes per device — proves it fits),
  - cost_analysis   (FLOPs / bytes for §Roofline),
  - per-chip collective wire bytes parsed from the partitioned HLO.

Results append to a JSONL ledger; already-present combos are skipped, so the
full sweep is resumable. Usage:

  python -m repro.launch.dryrun --arch llama3_2_3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""

import argparse
import json
import time
import traceback

import jax


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            config_overrides=None, verbose: bool = True):
    from repro.configs import base
    from repro.launch import steps as ST
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.roofline import analysis as RA

    cfg = base.get_config(arch)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    shape = base.INPUT_SHAPES[shape_name]
    reason = base.skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    # pin activations' batch axis inside scans when it divides the data axes
    # (H3.2: GSPMD otherwise replicates inner attention scans)
    if "batch_shard_axes" not in (config_overrides or {}):
        dp = ("pod", "data") if multi_pod else ("data",)
        dp_size = int(__import__("numpy").prod([mesh.shape[a] for a in dp]))
        eff_batch = shape.global_batch
        if shape.kind == "train":
            eff_batch //= max(cfg.microbatches, 1)
        if eff_batch % dp_size == 0:
            cfg = cfg.replace(batch_shard_axes=dp)
    t0 = time.time()

    p_shapes = ST.params_specs(cfg)
    p_specs = SH.param_pspecs(cfg, p_shapes, mesh)

    if shape.kind == "train":
        step, opt = ST.make_train_step(cfg)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_specs = {"m": p_specs, "v": p_specs, "t": SH.P()}
        b_shapes = ST.batch_specs(cfg, shape)
        b_specs = SH.batch_pspecs(cfg, shape, b_shapes, mesh)
        in_specs = (p_specs, o_specs, b_specs)
        out_specs = (p_specs, o_specs, None)
        args = (p_shapes, o_shapes, b_shapes)
    elif shape.kind == "prefill":
        step = ST.make_prefill_step(cfg)
        b_shapes = ST.batch_specs(cfg, shape)
        b_specs = SH.batch_pspecs(cfg, shape, b_shapes, mesh)
        with mesh:  # tracing hits with_sharding_constraint
            c_shapes = jax.eval_shape(
                lambda p, b: step(p, b)[1], p_shapes, b_shapes)
        c_specs = SH.cache_pspecs(cfg, c_shapes, mesh)
        in_specs = (p_specs, b_specs)
        out_specs = (None, c_specs)
        args = (p_shapes, b_shapes)
    else:  # decode
        step = ST.make_serve_step(cfg)
        c_shapes = ST.cache_specs(cfg, shape)
        c_specs = SH.cache_pspecs(cfg, c_shapes, mesh)
        t_shapes = ST.token_specs(cfg, shape)
        t_spec = SH.batch_pspecs(cfg, shape, {"token": t_shapes}, mesh)["token"]
        in_specs = (p_specs, c_specs, t_spec)
        out_specs = (None, c_specs)
        args = (p_shapes, c_shapes, t_shapes)

    with mesh:
        jitted = jax.jit(step,
                         in_shardings=SH.named(mesh, in_specs),
                         out_shardings=SH.named(mesh, out_specs))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_info[attr] = int(v)
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    cost = dict(cost or {})
    hlo = compiled.as_text()
    terms = RA.roofline_terms(cost, hlo, chips)

    n_params = sum(int(x.size) for x in jax.tree.leaves(p_shapes))
    n_active = RA.active_params(cfg, n_params)
    mf = RA.model_flops(cfg, shape, n_params, n_active)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": shape.kind,
        "n_params": n_params, "n_active_params": n_active,
        "model_flops": mf,
        "useful_flops_ratio": (mf / terms["flops"]) if terms["flops"] else 0.0,
        "memory": mem_info,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo),
        **{k: v for k, v in terms.items()},
    }
    if verbose:
        dom = rec["dominant"]
        print(f"[dryrun] {arch:16s} {shape_name:12s} {rec['mesh']:8s} "
              f"flops={terms['flops']:.3e} dom={dom} "
              f"t=({terms['t_compute_s']:.2e},{terms['t_memory_s']:.2e},"
              f"{terms['t_collective_s']:.2e})s compile={t_compile:.0f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    from repro.configs import base

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r.get("mesh", "")))
                except Exception:
                    pass

    combos = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in base.ARCH_IDS:
            for s in base.INPUT_SHAPES:
                for mp in meshes:
                    combos.append((a, s, mp))
    else:
        combos = [(args.arch, args.shape, mp) for mp in meshes]

    for a, s, mp in combos:
        mesh_name = "2x16x16" if mp else "16x16"
        if (a, s, mesh_name) in done:
            print(f"[dryrun] skip (done): {a} {s} {mesh_name}")
            continue
        reason = base.skip_reason(a, s)
        if reason:
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "skipped": reason}
        else:
            try:
                rec = run_one(a, s, multi_pod=mp)
            except Exception as e:
                rec = {"arch": a, "shape": s, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[dryrun] FAIL {a} {s} {mesh_name}: {e}")
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()

"""Production step functions + abstract input specs for the dry-run.

``train_step`` IS the paper's technique at scale: embed -> client-prefix
scan -> {local tied-head loss; server suffix + head loss} -> two-branch vjp
-> clip + TPGF fusion (Eqs. 3-4) -> AdamW. Gradient accumulation over
``cfg.microbatches`` keeps 4k-seq global-batch-256 activations inside HBM.

``serve_step`` / ``prefill_step`` are the single-token decode and
teacher-forced cache-building forward of the assembled super-network.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape
from repro.core import tpgf as T
from repro.models import decode as D
from repro.models import model as M
from repro.optim import adamw, apply_updates


def make_train_step(cfg: ModelConfig, opt=None):
    import jax.numpy as _jnp
    opt = opt or adamw(3e-4, weight_decay=0.1,
                       moment_dtype=_jnp.dtype(cfg.adam_moment_dtype))
    d = cfg.resolved_split_depth
    mb = max(cfg.microbatches, 1)

    def compute_grads(params, batch):
        if mb == 1:
            out = T.tpgf_grads(cfg, params, batch, d)
            metrics = {"loss_client": out.loss_client,
                       "loss_server": out.loss_server,
                       "w_client": out.w_client,
                       "aux": out.aux}
            return out.grads, metrics

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        mbatches = jax.tree.map(split, batch)

        def mb_step(acc, mbatch):
            out = T.tpgf_grads(cfg, params, mbatch, d)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / mb, acc, out.grads)
            return acc, (out.loss_client, out.loss_server, out.w_client)

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (lc, ls, wc) = jax.lax.scan(mb_step, acc0, mbatches)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        metrics = {"loss_client": jnp.mean(lc), "loss_server": jnp.mean(ls),
                   "w_client": jnp.mean(wc), "aux": jnp.float32(0.0)}
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return D.prefill(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token):
        return D.decode_step(cfg, params, cache, token)

    return serve_step


# ------------------------------------------------------------- input specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    if cfg.family == "vit":
        return {"images": _sds((B, cfg.image_size, cfg.image_size, 3), dt),
                "label": _sds((B,), i32)}
    if cfg.is_encdec:
        return {"frames": _sds((B, cfg.enc_frames, cfg.d_model), dt),
                "tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
    if cfg.family == "vlm":
        return {"patches": _sds((B, cfg.n_patches, cfg.d_model), dt),
                "tokens": _sds((B, S - cfg.n_patches), i32),
                "labels": _sds((B, S - cfg.n_patches), i32)}
    return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, shape: InputShape):
    return jax.eval_shape(
        functools.partial(D.init_cache, cfg, shape.global_batch,
                          shape.seq_len))


def token_specs(cfg: ModelConfig, shape: InputShape):
    return _sds((shape.global_batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Tuple:
    """Abstract args for the step that ``shape.kind`` exercises."""
    if shape.kind == "train":
        _, opt = make_train_step(cfg)
        p = params_specs(cfg)
        o = jax.eval_shape(opt.init, p)
        return (p, o, batch_specs(cfg, shape))
    if shape.kind == "prefill":
        return (params_specs(cfg), batch_specs(cfg, shape))
    return (params_specs(cfg), cache_specs(cfg, shape),
            token_specs(cfg, shape))

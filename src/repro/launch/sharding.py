"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (baseline, see EXPERIMENTS.md §Perf for iterations):
  - FSDP over ("pod","data"): the d_model ("input feature") dim of big
    projections and the embedding feature dim — required because grok-1's
    628 GB (bf16) cannot be replicated on 16 GB chips.
  - Tensor parallel over "model": vocab, flattened head dim (H*hd), d_ff,
    SSM d_inner. Every rule is divisibility-checked against the actual dim
    and falls back to replication (e.g. whisper's 12 heads x 64 hd = 768
    divides 16 even though 12 doesn't; mamba2's 80 ssm heads don't divide
    16 so dt/A/D stay replicated).
  - Batch over ("pod","data") wherever divisible; long_500k (B=1) shards
    the rolling KV window over "data" instead.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from repro.launch.mesh import TENSOR_AXIS, fsdp_axes


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh, dim: int, axes):
    """Return ``axes`` if the dim divides the mesh extent, else None."""
    if axes is None or dim is None:
        return None
    return axes if dim % _axis_size(mesh, axes) == 0 else None


def _spec_for(mesh, name: str, parent: str, shape, fsdp) -> P:
    nd = len(shape)
    t = TENSOR_AXIS

    def mk(*ax):
        # divisibility-check every proposed axis
        fixed = [None if a is None else _fit(mesh, shape[i], a)
                 for i, a in enumerate(ax)]
        return P(*fixed)

    stacked = nd >= 1 and parent in ("layers", "enc_layers", "dec_layers")
    off = 1 if stacked else 0

    if name == "embed":
        return mk(t, fsdp)
    if name in ("unembed", "local_head"):
        return mk(fsdp, t)
    if name in ("frame_proj", "vision_proj"):
        return mk(fsdp, t)
    if name in ("wq", "wk", "wv"):
        return mk(*([None] * off), fsdp, t)
    if name == "wo":
        return mk(*([None] * off), t, fsdp)
    if name in ("bq", "bk", "bv", "b_up"):
        return mk(*([None] * off), t)
    if name in ("w_gate", "w_up"):
        if nd - off == 3:                      # MoE expert weights [E,dm,dff]
            return mk(*([None] * off), None, fsdp, t)
        return mk(*([None] * off), fsdp, t)
    if name == "w_down":
        if nd - off == 3:
            return mk(*([None] * off), None, t, fsdp)
        return mk(*([None] * off), t, fsdp)
    if name == "router":
        return mk(*([None] * off), fsdp, None)
    if name in ("w_x", "w_z"):
        return mk(*([None] * off), fsdp, t)
    if name in ("w_B", "w_C", "w_dt"):
        return mk(*([None] * off), fsdp, None)
    if name == "w_out":
        return mk(*([None] * off), t, fsdp)
    if name == "conv_w":
        return mk(*([None] * off), None, t)
    if name in ("conv_b", "gate_norm_scale"):
        return mk(*([None] * off), t)
    return P()  # norms, scalars, positional tables, vit bits: replicate


def param_pspecs(cfg: ModelConfig, params_shapes, mesh) -> Dict[str, Any]:
    """PartitionSpec tree matching a params (shape) tree."""
    fsdp = fsdp_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k_, "key", getattr(k_, "idx", None)) for k_ in path]
        name = keys[-1]
        parent = keys[0]
        specs.append(_spec_for(mesh, name, parent, leaf.shape, fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(cfg: ModelConfig, shape: InputShape, batch_shapes, mesh
                 ) -> Dict[str, Any]:
    dp = fsdp_axes(mesh)

    def spec(path_leaf):
        name, leaf = path_leaf
        b = leaf.shape[0] if leaf.ndim else 1
        first = _fit(mesh, b, dp) if leaf.ndim else None
        rest = [None] * (leaf.ndim - 1)
        return P(first, *rest) if leaf.ndim else P()

    return {k: spec((k, v)) for k, v in batch_shapes.items()}


def cache_pspecs(cfg: ModelConfig, cache_shapes, mesh) -> Dict[str, Any]:
    dp = fsdp_axes(mesh)
    t = TENSOR_AXIS
    out: Dict[str, Any] = {}
    for k, v in cache_shapes.items():
        if k == "idx":
            out[k] = P()
        elif k == "pos":
            B, W = v.shape
            bax = _fit(mesh, B, dp)
            if cfg.decode_cache_shard == "seq":
                out[k] = P(bax, _fit(mesh, W, t))
            else:
                wax = None if bax else _fit(mesh, W, ("data",))
                out[k] = P(bax, wax)
        elif k in ("k", "v", "cross_k", "cross_v"):
            L_, B, W, K, hd = v.shape
            bax = _fit(mesh, B, dp)
            if cfg.decode_cache_shard == "seq":
                # flash-decode style: shard the sequence/window dim over the
                # tensor axis; per-chip partial attention + tiny stat
                # all-reduces instead of resharding the whole cache
                # (§Perf hillclimb H1)
                wax = _fit(mesh, W, t)
                out[k] = P(None, bax, wax, None, None)
            else:
                wax = None if bax else _fit(mesh, W, ("data",))
                kax = _fit(mesh, K, t)
                hax = None if kax else _fit(mesh, hd, t)
                out[k] = P(None, bax, wax, kax, hax)
        elif k == "ssm_h":
            L_, B, nh, hd, st = v.shape
            bax = _fit(mesh, B, dp)
            nax = _fit(mesh, nh, t)
            hax = None if nax else _fit(mesh, hd, t)
            out[k] = P(None, bax, nax, hax, None)
        elif k == "ssm_conv":
            L_, B, kk, din = v.shape
            out[k] = P(None, _fit(mesh, B, dp), None, _fit(mesh, din, t))
        else:
            out[k] = P()
    return out


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------- fleet (client) axis

def fleet_axes(mesh):
    """The mesh axes the fleet/client (and bucket-slot) dimension shards
    over — the data axes; also the ``psum`` axis names inside shard-mapped
    bucket kernels. This is the ONE source of truth for those names:
    ``FleetKernel`` threads it through every kernel's ``axis_name``
    parameter, and fleetlint's FL003 rule rejects hard-coded axis strings
    (plus kernels whose ``specs=`` leave any array argument or output
    leaf without :func:`slot_pspec` coverage)."""
    return fsdp_axes(mesh)


def fleet_extent(mesh) -> int:
    """Number of fleet shards: the product of the data-axis sizes. Bucket
    sizes round up to a multiple of this (``bucketing.bucket_size``'s
    ``multiple_of``) so every shard owns whole slots."""
    return _axis_size(mesh, fleet_axes(mesh))


def slot_pspec(slot_axis: int, axes) -> P:
    """PartitionSpec for a bucket-slot-leading kernel argument: the slot
    axis shards over the fleet ``axes``, every other dim replicates. Used
    as a tree-prefix spec, so one call covers a whole param-stack pytree
    (``slot_pspec(0, axes)``) or a [steps, bucket, B] index array
    (``slot_pspec(1, axes)``)."""
    return P(*([None] * slot_axis), axes)


def fleet_pspecs(tree, mesh) -> Dict[str, Any]:
    """PartitionSpecs for [N]-leading stacked fleet structures (the
    federated engine's stacked local heads / workspace buffers): shard the
    client axis over the data axes when N divides them, replicate the rest
    (scalar / 0-d leaves get the rank-0 spec ``P()``). Falls back to full
    replication for fleets smaller than the mesh — the divisibility check
    mirrors every other rule in this module."""
    dp = fsdp_axes(mesh)
    return jax.tree.map(
        lambda x: P(_fit(mesh, x.shape[0], dp),
                    *([None] * (x.ndim - 1))) if x.ndim else P(),
        tree)


def shard_fleet(tree, mesh):
    """Place a stacked fleet structure with the client axis sharded
    (``Engine(mesh=...)`` runs this on the stacked local heads so 100-client
    sweeps spread phi_i storage and kernel slots across devices)."""
    return jax.device_put(tree, named(mesh, fleet_pspecs(tree, mesh)))

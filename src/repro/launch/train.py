"""End-to-end SuperSFL training driver (deliverable (b), driver flavor).

Runs the production TPGF train step (the same function the dry-run lowers)
on synthetic Markov-chain LM data, on whatever devices exist — 1 CPU here,
a v5e pod with ``--mesh`` on real hardware. ``--reduced`` selects the smoke
variant so the driver is runnable in this container; the full config is the
same code path.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b --reduced \
      --steps 60 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import base
from repro.data.synthetic import synthetic_lm_batches
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--mesh", action="store_true",
                    help="run under the production mesh (needs >=256 devices)")
    args = ap.parse_args()

    cfg = (base.get_reduced(args.arch) if args.reduced
           else base.get_config(args.arch))
    cfg = cfg.replace(microbatches=1, dtype="float32" if args.reduced
                      else cfg.dtype)
    step_fn, opt = make_train_step(cfg, adamw(args.lr))
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        from repro.launch import sharding as SH
        from repro.launch import steps as ST
        mesh = make_production_mesh()
        p_specs = SH.param_pspecs(cfg, ST.params_specs(cfg), mesh)
        step_fn = jax.jit(step_fn, in_shardings=(
            SH.named(mesh, p_specs),
            SH.named(mesh, {"m": p_specs, "v": p_specs, "t": SH.P()}),
            None))
    else:
        step_fn = jax.jit(step_fn)

    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    n_params = M.param_count(params)
    opt_state = opt.init(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"split_depth={cfg.resolved_split_depth}/{cfg.split_stack_len}")

    t0 = time.time()
    history = []
    stream = synthetic_lm_batches(cfg.vocab, args.seq, args.batch,
                                  args.steps, seed=1)
    for i, npbatch in enumerate(stream):
        batch = {k: jax.numpy.asarray(v) for k, v in npbatch.items()}
        if cfg.family == "vlm":
            batch["patches"] = jax.numpy.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), cfg.dtype)
        if cfg.is_encdec:
            batch["frames"] = jax.numpy.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), cfg.dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            rec = {"step": i + 1, "elapsed_s": round(time.time() - t0, 1),
                   **{k: round(v, 4) for k, v in m.items()}}
            history.append(rec)
            print(json.dumps(rec))
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps,
                        meta={"arch": cfg.name})
        print(f"saved checkpoint to {args.ckpt}.npz")
    l0, l1 = history[0]["loss_server"], history[-1]["loss_server"]
    print(f"loss_server {l0:.3f} -> {l1:.3f} "
          f"({'LEARNING' if l1 < l0 else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()

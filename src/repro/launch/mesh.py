"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state; the 512-host-device dry-run and the 1-device test environment
coexist (system-prompt contract).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(repro.launch.dryrun sets this automatically)")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_fleet_mesh(n_devices: int = None):
    """1-D ``("data",)`` mesh over the host's devices for fleet/client-axis
    execution (``Engine(mesh=...)``): bucket kernels shard_map their slot
    axis over it, stacked fleet storage shards via
    ``launch.sharding.fleet_pspecs``. ``n_devices=None`` uses every device
    (force a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    import jax

    devices = jax.devices()
    n = len(devices) if n_devices is None else n_devices
    if len(devices) < n:
        raise RuntimeError(f"fleet mesh wants {n} devices, found "
                           f"{len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for unit tests (requires host-device override >= prod)."""
    import jax

    n = int(np.prod(shape))
    arr = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-free mesh for sharding-rule validation.

    jax >= 0.4.36 changed ``AbstractMesh`` to take ``((name, size), ...)``
    instead of ``(sizes, names)``; this helper accepts the old-style pair
    and builds whichever form the installed jax expects.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:        # older jax: positional (shape, axis_names)
        return AbstractMesh(shape, axes)


def fsdp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axes(mesh) -> tuple:
    return fsdp_axes(mesh)


TENSOR_AXIS = "model"

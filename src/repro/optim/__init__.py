from repro.optim.optimizers import (Optimizer, sgd, sgd_momentum, adamw,
                                    apply_updates, get_optimizer)  # noqa: F401

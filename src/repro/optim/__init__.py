from repro.optim.optimizers import (Optimizer, sgd, sgd_momentum, adamw,
                                    fedadam, fedyogi,
                                    apply_updates, get_optimizer,
                                    map_moments)  # noqa: F401

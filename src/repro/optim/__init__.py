from repro.optim.optimizers import (Optimizer, sgd, sgd_momentum, adamw,
                                    apply_updates)  # noqa: F401

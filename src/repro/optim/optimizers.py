"""Minimal pytree optimizers (no external deps).

API mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``, then
``apply_updates``. AdamW keeps fp32 moments regardless of param dtype
(production precision policy, DESIGN.md §7).

State-shape contract (relied on by the federated strategies to persist the
shared server branch's moments across rounds, see ``TrainState.opt_state``):
an optimizer state is either an empty tuple (stateless) or a flat dict whose
entries are

  * *moment entries* — pytrees mirroring the ``params`` tree exactly
    (``"mu"`` for momentum, ``"m"``/``"v"`` for AdamW), or
  * *bookkeeping entries* — scalars and counters (AdamW's ``"t"``).

``map_moments`` distinguishes the two structurally, so strategy code can
slice / broadcast / reduce moments without knowing which optimizer is
plugged in.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]


def get_optimizer(name: str, lr: float, **kw) -> "Optimizer":
    """Resolve an optimizer by name — the pluggable hook used by the
    federated engine (``Engine(optimizer="sgd_momentum")``).

    Identical (name, lr, kw) resolve to the SAME instance: the engine
    passes the optimizer as a jit static argument (keyed by identity), so
    sharing the instance shares the compiled cohort kernels across engines.
    """
    if name not in _OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; "
                       f"available: {sorted(_OPTIMIZERS)}")
    return _cached_optimizer(name, lr, tuple(sorted(kw.items())))


@functools.lru_cache(maxsize=None)
def _cached_optimizer(name: str, lr: float, kw_items: tuple) -> "Optimizer":
    return _OPTIMIZERS[name](lr, **dict(kw_items))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def map_moments(fn: Callable[[Any], Any], state, params):
    """Apply ``fn`` to each moment entry of an optimizer ``state``.

    A *moment entry* is a state entry whose tree structure equals that of
    ``params`` (the contract in the module docstring); bookkeeping entries
    (step counters) and stateless ``()`` states pass through untouched.
    ``fn`` receives the whole mirrored pytree, so callers can slice the
    split stack, broadcast to a client axis, or reduce over it.
    """
    if not isinstance(state, dict):
        return state
    pdef = jax.tree_util.tree_structure(params)
    return {k: fn(v)
            if jax.tree_util.tree_structure(v) == pdef else v
            for k, v in state.items()}


def sgd(lr: float) -> Optimizer:
    """Plain SGD: ``p <- p - lr * g``. Stateless (state is ``()``)."""
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def sgd_momentum(lr: float, momentum: float = 0.9) -> Optimizer:
    """Heavy-ball momentum, fp32 accumulator:

        mu <- momentum * mu + g
        p  <- p - lr * mu
    """
    def init(params):
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        return jax.tree.map(lambda m: -lr * m, mu), {"mu": mu}

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    """Decoupled-weight-decay Adam (Loshchilov & Hutter):

        t <- t + 1
        m <- b1 * m + (1 - b1) * g          (stored in ``moment_dtype``)
        v <- b2 * v + (1 - b2) * g^2
        p <- p - lr * [ (m / (1 - b1^t)) / (sqrt(v / (1 - b2^t)) + eps)
                        + weight_decay * p ]

    All arithmetic runs in fp32; ``moment_dtype=jnp.bfloat16`` halves
    optimizer HBM (314B-param models on 16 GB chips are
    optimizer-state-bound; see EXPERIMENTS.md §Perf H2). The ``t`` counter
    is shared bookkeeping, NOT a moment entry — it counts ``update`` calls,
    so a state restored from a checkpoint resumes bias correction exactly
    where it left off.
    """
    def init(params):
        z = lambda p: jnp.zeros_like(p, moment_dtype)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)
                           ).astype(moment_dtype), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2)
                           * jnp.square(g.astype(jnp.float32))
                           ).astype(moment_dtype), state["v"], grads)
        c1 = 1.0 - b1 ** tf
        c2 = 1.0 - b2 ** tf

        def upd(m_, v_, p):
            m32 = m_.astype(jnp.float32)
            v32 = v_.astype(jnp.float32)
            step = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def _fedopt(lr: float, b1: float, b2: float, eps: float,
            v_rule: Callable) -> Optimizer:
    """Shared FedOpt skeleton (Reddi et al., Adaptive Federated
    Optimization — no bias correction): first moment and step are common,
    ``v_rule(v, g2)`` supplies the second-moment recursion. All fp32."""
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: v_rule(v_, jnp.square(g.astype(jnp.float32))),
            state["v"], grads)
        upd = jax.tree.map(lambda m_, v_: -lr * m_ / (jnp.sqrt(v_) + eps),
                           m, v)
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def fedadam(lr: float, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    """FedAdam (Reddi et al.) — the server-side Adam of the FedOpt
    family, WITHOUT bias correction:

        m <- b1 * m + (1 - b1) * g
        v <- b2 * v + (1 - b2) * g^2
        p <- p - lr * m / (sqrt(v) + eps)

    ``g`` is the server pseudo-gradient (``theta_old - theta_avg`` in the
    federated fold; any descent direction works). ``eps`` defaults to the
    paper's tau = 1e-3 — much larger than Adam's classic 1e-8, it bounds
    the per-coordinate step early on. State is two fp32 moment entries
    (``"m"``/``"v"``), so it persists in ``opt_state["server"]`` exactly
    like ``fedavgm``'s momentum and slices through ``map_moments``.
    """
    return _fedopt(lr, b1, b2, eps,
                   lambda v, g2: b2 * v + (1 - b2) * g2)


def fedyogi(lr: float, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    """FedYogi (Reddi et al.): FedAdam with Yogi's additive second-moment
    rule, which forgets stale variance much more slowly than Adam's
    multiplicative decay when gradients shrink:

        v <- v - (1 - b2) * g^2 * sign(v - g^2)

    Same state contract as :func:`fedadam` (``"m"``/``"v"`` fp32 moment
    entries in ``opt_state["server"]``, ``map_moments``-sliceable).
    """
    return _fedopt(lr, b1, b2, eps,
                   lambda v, g2: v - (1 - b2) * g2 * jnp.sign(v - g2))


_OPTIMIZERS = {"sgd": sgd, "sgd_momentum": sgd_momentum, "adamw": adamw,
               "fedadam": fedadam, "fedyogi": fedyogi}

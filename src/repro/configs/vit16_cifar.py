from repro.configs.base import ModelConfig

# The paper's own experimental backbone: ViT-16 adapted to CIFAR
# (patchified 32x32 images, classifier head). Used by the federated
# simulator + paper-validation benchmarks, not part of the 10x4 matrix.
CONFIG = ModelConfig(
    name="vit16-cifar", family="vit", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=0,
    n_classes=10, image_size=32, patch_size=4, mlp="gelu",
    norm="layernorm", dtype="float32",
)  # [arXiv:2010.11929] ViT-Base/16 geometry on CIFAR

def reduced():
    return CONFIG.replace(
        name="vit-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, n_classes=10,
        image_size=16, patch_size=4)

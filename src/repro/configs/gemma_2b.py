from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=256000,
    mlp="geglu", norm="rmsnorm", dtype="bfloat16", remat=True, microbatches=2,
)  # [arXiv:2403.08295] GeGLU, head_dim=256, MQA

def reduced():
    return CONFIG.replace(
        name="gemma-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=1, head_dim=32, d_ff=256, vocab=512,
        dtype="float32", remat=False)

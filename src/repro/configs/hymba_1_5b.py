from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32001,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, mlp="swiglu",
    norm="rmsnorm", dtype="bfloat16", remat=True, microbatches=2,
)  # [arXiv:2411.13676] parallel attention + mamba heads per layer

def reduced():
    return CONFIG.replace(
        name="hymba-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, ssm_state=8,
        ssm_head_dim=32, dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, head_dim=128, d_ff=11008, vocab=151936,
    qkv_bias=True, mlp="swiglu", norm="rmsnorm", rope_theta=1000000.0,
    dtype="bfloat16", remat=True, microbatches=4,
)  # [hf:Qwen/Qwen2.5-0.5B family] GQA kv=2, QKV bias

def reduced():
    return CONFIG.replace(
        name="qwen2.5-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128,
    ssm_expand=2, ssm_head_dim=64, norm="rmsnorm",
    dtype="bfloat16", remat=True, microbatches=4,
)  # [arXiv:2405.21060] SSD (state-space duality), attention-free

def reduced():
    return CONFIG.replace(
        name="mamba2-reduced", n_layers=2, d_model=128, vocab=512,
        ssm_state=16, ssm_head_dim=32, dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=51865,
    n_enc_layers=12, enc_frames=1500, mlp="gelu", norm="layernorm",
    tie_embeddings=True, dtype="bfloat16", remat=True, microbatches=1,
)  # [arXiv:2212.04356] enc-dec; conv/mel frontend is a stub

def reduced():
    return CONFIG.replace(
        name="whisper-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        n_enc_layers=2, enc_frames=16, dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, sliding_window=4096, mlp="swiglu",
    norm="rmsnorm", tie_embeddings=False, dtype="bfloat16", remat=True, microbatches=4,
)  # [arXiv:2401.04088] 8 experts top-2, sliding-window attention

def reduced():
    return CONFIG.replace(
        name="mixtral-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, n_experts=4,
        top_k=2, sliding_window=16, dtype="float32", remat=False)

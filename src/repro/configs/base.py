"""Config system: ModelConfig dataclass + input-shape registry.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact assigned full-size config) and ``reduced()``
(a smoke-test variant of the same family: <=2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MLP / norm flavour ---
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    router_aux_coef: float = 0.01
    moe_dispatch: str = "dense"      # dense | gather (capacity-based)
    moe_capacity_factor: float = 2.0
    # --- attention windowing ---
    sliding_window: int = 0          # 0 = full attention
    long_context_window: int = 8192  # used for long_500k decode variant
    # --- sharding variants (§Perf hillclimbs; "heads" = paper-era baseline)
    decode_cache_shard: str = "heads"   # heads | seq
    adam_moment_dtype: str = "float32"  # float32 | bfloat16 (fit lever, §Perf H2)
    attn_block_skip: bool = False       # skip fully-masked kv blocks
    # activation sharding constraints: batch axes to pin inside the layer
    # scan (GSPMD otherwise replicates the blockwise-attention inner scans
    # when head counts don't divide the tensor axis — §Perf H3.2). Empty
    # tuple = no constraints (CPU/test path).
    batch_shard_axes: tuple = ()
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_dim: int = 4
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0            # >0 => encoder-decoder
    enc_frames: int = 1500           # stub audio frontend output length
    # --- VLM ---
    n_patches: int = 0               # >0 => vision stub prepends patch embeds
    # --- ViT classifier (the paper's own model) ---
    n_classes: int = 0               # >0 => image classifier, vocab ignored
    image_size: int = 32
    patch_size: int = 4
    # --- SuperSFL knobs (paper defaults) ---
    split_depth: int = 0             # 0 -> n_layers // 4 (min 1)
    tpgf_variant: str = "full"       # full | no_loss | no_depth | equal (Fig.6)
    tpgf_clip: float = 0.5
    tpgf_eps: float = 1e-8
    agg_lambda: float = 0.01
    alloc_alpha: float = 0.5
    alloc_beta: float = 4.0
    # --- runtime ---
    dtype: str = "float32"           # activations/params dtype for this config
    remat: bool = False
    use_pallas: bool = False
    microbatches: int = 1            # gradient accumulation steps

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 'model'."""
        return _round_up(self.vocab, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def resolved_split_depth(self) -> int:
        """Default SuperSFL split point: a quarter of the (client-visible) stack."""
        stack = self.n_enc_layers if self.is_encdec else self.n_layers
        d = self.split_depth or max(stack // 4, 1)
        return min(max(d, 1), stack - 1) if stack > 1 else 1

    @property
    def split_stack_len(self) -> int:
        """Length of the stack the split point indexes into."""
        return self.n_enc_layers if self.is_encdec else self.n_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "grok_1_314b",
    "internvl2_2b",
    "qwen2_5_3b",
    "whisper_small",
    "mixtral_8x7b",
    "llama3_2_3b",
    "internlm2_1_8b",
    "mamba2_2_7b",
    "gemma_2b",
    "hymba_1_5b",
]

# The paper's own backbone (ViT-16 on CIFAR) — extra, not in the 10x4 matrix.
EXTRA_ARCH_IDS = ["vit16_cifar"]


def canonical_id(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.reduced()


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    """Return a reason string if (arch, shape) is skipped, else None."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and cfg.is_encdec:
        return ("enc-dec ASR decoder has no 500k autoregressive regime "
                "(cross-attn over fixed 1500-frame encoder output); "
                "see DESIGN.md shape/skip matrix")
    return None


def all_combos() -> Tuple[Tuple[str, str], ...]:
    out = []
    for a in ARCH_IDS:
        for s in INPUT_SHAPES:
            if skip_reason(a, s) is None:
                out.append((a, s))
    return tuple(out)

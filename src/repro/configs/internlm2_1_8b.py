from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92544,
    mlp="swiglu", norm="rmsnorm", dtype="bfloat16", remat=True, microbatches=2,
)  # [arXiv:2403.17297] GQA kv=8

def reduced():
    return CONFIG.replace(
        name="internlm2-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        dtype="float32", remat=False)

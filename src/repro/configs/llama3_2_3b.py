from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=128256,
    mlp="swiglu", norm="rmsnorm", rope_theta=500000.0,
    dtype="bfloat16", remat=True, microbatches=4,
)  # [hf:meta-llama/Llama-3.2 family] small llama3, tied embeddings

def reduced():
    return CONFIG.replace(
        name="llama3.2-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92553,
    n_patches=256, mlp="swiglu", norm="rmsnorm", dtype="bfloat16",
    remat=True,
)  # [arXiv:2404.16821] InternViT (stub) + InternLM2 backbone

def reduced():
    return CONFIG.replace(
        name="internvl2-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, n_patches=16,
        dtype="float32", remat=False)

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, mlp="swiglu", norm="rmsnorm",
    tie_embeddings=False, dtype="bfloat16", remat=True, microbatches=8,
)  # [hf:xai-org/grok-1] 8 experts top-2

def reduced():
    return CONFIG.replace(
        name="grok-1-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, n_experts=4,
        top_k=2, dtype="float32", remat=False)

"""Shared neural-net building blocks (pure-function style, pytree params).

Everything here is written so that per-layer parameter trees can be stacked
along a leading ``L`` axis and consumed by ``jax.lax.scan`` — that stacked
tree IS the weight-sharing super-network (see DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------- init utils

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------- norms

def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, x, p, prefix: str):
    if cfg.norm == "layernorm":
        return layernorm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"])
    return rmsnorm(x, p[f"{prefix}_scale"])


def norm_params(cfg: ModelConfig, dm: int, dtype):
    if cfg.norm == "layernorm":
        return {"scale": ones((dm,), dtype), "bias": zeros((dm,), dtype)}
    return {"scale": zeros((dm,), dtype)}  # rmsnorm stores (scale - 1)


# ---------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, N, Hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

def attention(q, k, v, *, mask=None):
    """Reference attention with GQA broadcast.

    q: [B, Sq, H, hd]; k, v: [B, Sk, K, hd] with H % K == 0.
    mask: broadcastable to [B, H, Sq, Sk] (True = attend).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.reshape(B, Sq, K, G, hd)
    # keep operands in their storage dtype (bf16 cache stays bf16 in HBM);
    # the MXU accumulates in fp32 via preferred_element_type (§Perf H1.2)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qf, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = scores.reshape(B, H, Sq, k.shape[1])
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs.reshape(B, K, G, Sq, k.shape[1])
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def make_attn_mask(pos_q, pos_k, *, causal: bool, window: int = 0,
                   valid_k=None):
    """Build [B, 1, Sq, Sk] boolean mask from absolute positions.

    pos_q: [B, Sq]; pos_k: [B, Sk]; window>0 limits lookback distance;
    valid_k: [B, Sk] bool marks which cache slots are populated.
    """
    dq = pos_q[:, :, None]
    dk = pos_k[:, None, :]
    m = jnp.ones(dq.shape[:2] + (pos_k.shape[-1],), bool)
    if causal:
        m = m & (dk <= dq)
    if window and window > 0:
        m = m & (dk > dq - window)
    if valid_k is not None:
        m = m & valid_k[:, None, :]
    return m[:, None, :, :]


def attn_params(cfg: ModelConfig, key, dtype, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    H, K, dm = cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], dm, H * hd, dtype),
        "wk": dense_init(ks[1], dm, K * hd, dtype),
        "wv": dense_init(ks[2], dm, K * hd, dtype),
        "wo": dense_init(ks[3], H * hd, dm, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H * hd,), dtype)
        p["bk"] = zeros((K * hd,), dtype)
        p["bv"] = zeros((K * hd,), dtype)
    return p


def project_qkv(cfg: ModelConfig, p, xq, xkv):
    """Returns q [B,Sq,H,hd], k,v [B,Skv,K,hd]."""
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    return (q.reshape(B, Sq, H, hd), k.reshape(B, Skv, K, hd),
            v.reshape(B, Skv, K, hd))


# ----------------------------------------------------------------------- mlp

def mlp_params(cfg: ModelConfig, key, dtype):
    dm, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], dm, dff, dtype),
            "w_up": dense_init(ks[1], dm, dff, dtype),
            "w_down": dense_init(ks[2], dff, dm, dtype, scale=down_scale),
        }
    return {  # plain gelu
        "w_up": dense_init(ks[0], dm, dff, dtype),
        "b_up": zeros((dff,), dtype),
        "w_down": dense_init(ks[1], dff, dm, dtype, scale=down_scale),
        "b_down": zeros((dm,), dtype),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.mlp == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return (jax.nn.gelu(x @ p["w_up"] + p["b_up"])) @ p["w_down"] + p["b_down"]


# ------------------------------------------------------- blockwise attention

ATTN_BLOCKWISE_THRESHOLD = 4096


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        bq: int = 512, bk: int = 1024,
                        skip_masked_blocks: bool = False):
    """Flash-style online-softmax attention in pure XLA (lax.scan over query
    and kv blocks). Never materializes [B, H, Sq, Skv]; peak score block is
    [B, H, bq, bk] fp32. This is the lowering path used by the multi-pod
    dry-run for long sequences — the Pallas kernel in
    ``repro/kernels/flash_attention`` is the TPU-native equivalent.

    ``skip_masked_blocks`` (§Perf hillclimb) unrolls the query-block loop in
    Python so each q block only visits the kv blocks its causal/window band
    actually touches — ~2x FLOP cut for causal, ~S/window for windowed — at
    the cost of nq-times-larger HLO.

    Positions are assumed to be arange (training/prefill self-attention).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(hd)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, K, G, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, K, hd), 1, 0)

    def make_kv_step(i):
        def kv_step(carry, kv_j):
            m, l, acc = carry
            kj, vj, j = kv_j
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi_ref[0], kj,
                           preferred_element_type=jnp.float32) * scale
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask = mask & (cols <= rows)
            if window:
                mask = mask & (cols > rows - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None
        return kv_step

    qi_ref = [None]

    def run_q_block(qi, i, kv_lo, kv_hi):
        """Online softmax of q block i over kv blocks [kv_lo, kv_hi]."""
        qi_ref[0] = qi
        init = (jnp.full((B, K, G, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, bq), jnp.float32),
                jnp.zeros((B, K, G, bq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            make_kv_step(i), init,
            (kb[kv_lo:kv_hi + 1], vb[kv_lo:kv_hi + 1],
             jnp.arange(kv_lo, kv_hi + 1)))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    if skip_masked_blocks:
        outs = []
        for i in range(nq):
            hi = min((i + 1) * bq - 1, Sq - 1) // bk if causal else nk - 1
            lo = max(0, (i * bq - window + 1) // bk) if window else 0
            outs.append(run_q_block(qb[i], i, lo, hi))
        outs = jnp.stack(outs)
    else:
        def q_step(_, qi_and_i):
            qi, i = qi_and_i
            qi_ref[0] = qi
            init = (jnp.full((B, K, G, bq), NEG_INF, jnp.float32),
                    jnp.zeros((B, K, G, bq), jnp.float32),
                    jnp.zeros((B, K, G, bq, hd), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(
                make_kv_step(i), init, (kb, vb, jnp.arange(nk)))
            return None, (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

        _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    # outs: [nq, B, K, G, bq, hd] -> [B, Sq, H, hd]
    outs = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    return outs.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


# -------------------------------------------------------------------- losses

def softmax_xent(logits, labels, *, valid=None, vocab: Optional[int] = None):
    """Mean cross-entropy in fp32. logits [..., V]; labels [...] int.

    ``vocab`` masks padded vocabulary columns (see padded_vocab in configs).
    ``valid`` (same shape as labels) masks ignored positions.
    """
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        pad = logits.shape[-1] - vocab
        neg = jnp.full(logits.shape[:-1] + (pad,), NEG_INF, logits.dtype)
        logits = jnp.concatenate([logits[..., :vocab], neg], axis=-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if valid is None:
        return jnp.mean(nll)
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)

"""KV/SSM-cache serving path: prefill + single-token decode.

Cache layout (stacked over layers, mirroring the super-network stack):
  attention:  k, v      [L, B, W, K, hd]   (W = rolling window, see below)
  ssm:        ssm_h     [L, B, nh, hd, st] fp32
              ssm_conv  [L, B, k-1, d_inner]
  whisper:    cross_k/v [L, B, T_enc, K, hd] (computed once at prefill)
  shared:     pos [B, W] int32 (absolute position per slot, -1 = empty),
              idx scalar int32 (next position to decode)

W (the cache window) makes ``long_500k`` sub-quadratic AND sub-linear in
memory for attention archs: a rolling buffer of ``long_context_window``
(or the arch's native sliding window, e.g. mixtral's 4096) — the 500k KV
cache is never materialized (DESIGN.md shape/skip matrix).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models import moe as MOE
from repro.models.model import (layer_role, embed_inputs, run_stack,
                                _head_logits)

LONG_CONTEXT_THRESHOLD = 65536


def cache_window(cfg: ModelConfig, seq_len: int) -> int:
    w = cfg.sliding_window or 0
    if seq_len > LONG_CONTEXT_THRESHOLD:
        w = w or cfg.long_context_window
    return min(seq_len, w) if w else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    role = layer_role(cfg)
    W = cache_window(cfg, seq_len)
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    nL = cfg.n_layers
    c: Dict[str, Any] = {
        "idx": jnp.zeros((), jnp.int32),
        "pos": jnp.full((batch, W), -1, jnp.int32),
    }
    if role in ("dense", "moe", "enc", "hybrid") or cfg.is_encdec:
        c["k"] = jnp.zeros((nL, batch, W, K, hd), dtype)
        c["v"] = jnp.zeros((nL, batch, W, K, hd), dtype)
    if role in ("ssm", "hybrid"):
        c["ssm_h"] = jnp.zeros((nL, batch, cfg.ssm_n_heads, cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32)
        c["ssm_conv"] = jnp.zeros((nL, batch, cfg.ssm_conv_dim - 1,
                                   cfg.ssm_d_inner), dtype)
    if cfg.is_encdec:
        c["cross_k"] = jnp.zeros((nL, batch, cfg.enc_frames, K, hd), dtype)
        c["cross_v"] = jnp.zeros((nL, batch, cfg.enc_frames, K, hd), dtype)
    return c


# -------------------------------------------------------------------- prefill

def prefill(cfg: ModelConfig, params, batch, decode_budget: int = 0):
    """Teacher-forced full forward that also populates the cache.

    ``decode_budget`` reserves cache room for subsequent decode_step calls
    (ignored when the rolling window is already smaller than the prompt).
    """
    if cfg.family == "vit":
        raise ValueError("encoder-only classifier has no decode path")
    role = layer_role(cfg)
    if cfg.is_encdec:
        h, pos = embed_inputs(cfg, params, batch)  # encoder frames
        enc_out, _ = run_stack(cfg, params["enc_layers"], h, role="enc",
                               positions=pos, causal=False)
        enc_out = L.apply_norm(cfg, enc_out, {
            f"attn_norm_{k}": v for k, v in params["enc_norm"].items()},
            "attn_norm")
        tok = batch["tokens"]
        hd_ = params["embed"][tok] * math.sqrt(cfg.d_model)
        hd_ = hd_ + params["dec_pos"][:tok.shape[1]][None]
        dpos = jnp.broadcast_to(jnp.arange(tok.shape[1]), tok.shape)
        hdec, _, ys = run_stack(cfg, params["dec_layers"], hd_, role="dec",
                                positions=dpos, causal=True, enc_out=enc_out,
                                emit=True)
        hdec = L.apply_norm(cfg, hdec, {
            f"attn_norm_{k}": v for k, v in params["dec_norm"].items()},
            "attn_norm")
        logits = _head_logits(cfg, params, hdec)
        S = tok.shape[1]
        cache = _build_cache(cfg, ys, tok.shape[0], S, decode_budget)
        return logits, cache
    h, pos = embed_inputs(cfg, params, batch)
    causal = role in ("dense", "moe", "hybrid")
    h, _, ys = run_stack(cfg, params["layers"], h, role=role, positions=pos,
                         causal=causal, window=cfg.sliding_window, emit=True)
    h = L.apply_norm(cfg, h, {
        f"attn_norm_{k}": v for k, v in params["final_norm"].items()},
        "attn_norm")
    logits = _head_logits(cfg, params, h)
    cache = _build_cache(cfg, ys, h.shape[0], h.shape[1], decode_budget)
    return logits, cache


def _build_cache(cfg: ModelConfig, ys, batch: int, S: int,
                 decode_budget: int = 0):
    W = cache_window(cfg, S + decode_budget)
    c: Dict[str, Any] = {"idx": jnp.int32(S)}
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (batch, S))
    if "k" in (ys or {}):
        k, v = ys["k"], ys["v"]
        if W > S:  # pad headroom for decode
            padk = [(0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)]
            k = jnp.pad(k, padk)
            v = jnp.pad(v, padk)
            pos = jnp.pad(pos, [(0, 0), (0, W - S)], constant_values=-1)
        elif W < S:
            k, v, pos = k[:, :, S - W:], v[:, :, S - W:], pos[:, S - W:]
            # rolling-slot alignment: slot = position % W
            shift = (S - W) % W
            k = jnp.roll(k, shift, axis=2)
            v = jnp.roll(v, shift, axis=2)
            pos = jnp.roll(pos, shift, axis=1)
        c["k"], c["v"] = k, v
        c["pos"] = pos
    else:
        if W > S:
            pos = jnp.pad(pos, [(0, 0), (0, W - S)], constant_values=-1)
        c["pos"] = pos[:, :W] if W < S else pos
    if "ssm_h" in (ys or {}):
        c["ssm_h"] = ys["ssm_h"]
        c["ssm_conv"] = ys["ssm_conv"]
    if "cross_k" in (ys or {}):
        c["cross_k"] = ys["cross_k"]
        c["cross_v"] = ys["cross_v"]
    return c


# ---------------------------------------------------------------- decode step

def decode_step(cfg: ModelConfig, params, cache, token):
    """token [B, 1] int32 -> (logits [B, 1, V], new cache)."""
    if cfg.family == "vit":
        raise ValueError("encoder-only classifier has no decode path")
    role = "dec" if cfg.is_encdec else layer_role(cfg)
    dm = cfg.d_model
    B = token.shape[0]
    idx = cache["idx"]
    h = params["embed"][token] * math.sqrt(dm)
    if cfg.is_encdec:
        h = h + params["dec_pos"][idx][None, None, :]
    pos_q = jnp.full((B, 1), idx, jnp.int32)

    has_attn = "k" in cache
    if has_attn:
        W = cache["k"].shape[2]
        slot = idx % W
        pos_new = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((B, 1), idx, jnp.int32), (0, slot))
        valid = pos_new >= 0
    else:
        pos_new = cache["pos"]
        valid = None

    def attn_branch(p, attn_p, x, kc, vc):
        hd = cfg.resolved_head_dim
        H, K = cfg.n_heads, cfg.n_kv_heads
        q = x @ attn_p["wq"]
        k = x @ attn_p["wk"]
        v = x @ attn_p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + attn_p["bq"], k + attn_p["bk"], v + attn_p["bv"]
        q = q.reshape(B, 1, H, hd)
        k = k.reshape(B, 1, K, hd)
        v = v.reshape(B, 1, K, hd)
        if not cfg.is_encdec:
            q = L.apply_rope(q, pos_q, cfg.rope_theta)
            k = L.apply_rope(k, pos_q, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        mask = valid[:, None, None, :]
        out = L.attention(q, kc, vc, mask=mask)
        return out.reshape(B, 1, -1) @ attn_p["wo"], kc, vc

    def body(carry, xs):
        h, = carry
        p = xs["p"]
        ys = {}
        if role in ("dense", "moe", "dec", "hybrid"):
            x = L.apply_norm(cfg, h, p, "attn_norm")
            out, kc, vc = attn_branch(p, p["attn"], x, xs["k"], xs["v"])
            ys["k"], ys["v"] = kc, vc
            if role != "hybrid":
                h = h + out
        if role in ("ssm", "hybrid"):
            x = L.apply_norm(cfg, h, p, "attn_norm")
            s, st = SSM.ssm_decode_step(cfg, p["ssm"], x,
                                        {"h": xs["ssm_h"],
                                         "conv": xs["ssm_conv"]})
            ys["ssm_h"], ys["ssm_conv"] = st["h"], st["conv"]
            if role == "hybrid":
                h = h + p["branch_scale_attn"] * out + p["branch_scale_ssm"] * s
            else:
                h = h + s
        if role == "dec":
            x = L.apply_norm(cfg, h, p, "cross_norm")
            hd_ = cfg.resolved_head_dim
            q = (x @ p["cross"]["wq"]).reshape(B, 1, cfg.n_heads, hd_)
            out = L.attention(q, xs["cross_k"], xs["cross_v"], mask=None)
            h = h + out.reshape(B, 1, -1) @ p["cross"]["wo"]
        if role in ("dense", "dec", "hybrid"):
            x = L.apply_norm(cfg, h, p, "mlp_norm")
            h = h + L.mlp_apply(cfg, p["mlp"], x)
        elif role == "moe":
            x = L.apply_norm(cfg, h, p, "mlp_norm")
            y, _ = MOE.moe_apply(cfg, p["moe"], x)
            h = h + y
        return (h,), ys

    stack_name = "dec_layers" if cfg.is_encdec else "layers"
    xs = {"p": params[stack_name]}
    for key in ("k", "v", "ssm_h", "ssm_conv", "cross_k", "cross_v"):
        if key in cache:
            xs[key] = cache[key]
    (h,), ys = jax.lax.scan(body, (h,), xs)

    norm_name = "dec_norm" if cfg.is_encdec else "final_norm"
    h = L.apply_norm(cfg, h, {
        f"attn_norm_{k}": v for k, v in params[norm_name].items()},
        "attn_norm")
    logits = _head_logits(cfg, params, h)

    new_cache = dict(cache)
    new_cache["idx"] = idx + 1
    new_cache["pos"] = pos_new
    for key in ("k", "v", "ssm_h", "ssm_conv"):
        if key in ys:
            new_cache[key] = ys[key]
    return logits, new_cache

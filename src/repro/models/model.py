"""Unified stacked-layer model zoo.

Every architecture is expressed as (embed) -> scan over a STACKED layer
parameter tree -> (norm, head). The stacked tree (leading ``L`` axis) is the
weight-sharing super-network of the paper: a client subnetwork of depth ``d``
is literally ``tree_map(lambda p: p[:d], stack)`` — or, when ``d`` is a jax
value rather than a Python int, a masked scan over the FULL stack in which
inactive rows pass the carry through unchanged (``static_depth`` picks the
path). The masked form makes depth a runtime quantity: one jit program
serves every depth tier, and its active-layer math is bit-exact vs the
static slice.

Public surface used by the SuperSFL core and the launcher:
  init_params(cfg, rng)
  static_depth(d)                              -> bool   trace-time depth?
  prefix_apply(cfg, params, batch, d)          -> (z, aux)   smashed data
  local_logits(cfg, params, z)                 -> logits     client head
  suffix_apply(cfg, params, z, batch, d)       -> (logits, aux) server branch
  local_loss / server_loss / full_loss
  prefill(cfg, params, batch)                  -> (logits, cache)
  decode_step(cfg, params, cache, batch)       -> (logits, cache)
  make_dummy_batch(cfg, shape, rng)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, InputShape
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ----------------------------------------------------------------- stack init

def _layer_params(cfg: ModelConfig, key, dtype, *, role: str) -> Params:
    """One layer's parameter tree. role: dense|moe|ssm|hybrid|enc|dec."""
    ks = jax.random.split(key, 8)
    dm = cfg.d_model
    p: Params = {}
    if role in ("dense", "moe", "hybrid", "enc", "dec"):
        p.update({f"attn_norm_{k}": v
                  for k, v in L.norm_params(cfg, dm, dtype).items()})
        p["attn"] = L.attn_params(cfg, ks[0], dtype)
    if role == "dec":
        p.update({f"cross_norm_{k}": v
                  for k, v in L.norm_params(cfg, dm, dtype).items()})
        p["cross"] = L.attn_params(cfg, ks[1], dtype)
    if role in ("dense", "moe", "hybrid", "enc", "dec"):
        p.update({f"mlp_norm_{k}": v
                  for k, v in L.norm_params(cfg, dm, dtype).items()})
        if role == "moe":
            p["moe"] = MOE.moe_params(cfg, ks[2], dtype)
        else:
            p["mlp"] = L.mlp_params(cfg, ks[2], dtype)
    if role in ("ssm", "hybrid"):
        if role == "ssm":
            p.update({f"attn_norm_{k}": v
                      for k, v in L.norm_params(cfg, dm, dtype).items()})
        p["ssm"] = SSM.ssm_params(cfg, ks[3], dtype)
    if role == "hybrid":
        p["branch_scale_attn"] = jnp.ones((dm,), dtype)
        p["branch_scale_ssm"] = jnp.ones((dm,), dtype)
    return p


def _stack(cfg: ModelConfig, key, n: int, dtype, role: str) -> Params:
    keys = jax.random.split(key, n)
    per = [_layer_params(cfg, k, dtype, role=role) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def layer_role(cfg: ModelConfig) -> str:
    return {"dense": "dense", "moe": "moe", "ssm": "ssm", "hybrid": "hybrid",
            "vlm": "dense", "audio": "enc", "vit": "enc"}[cfg.family]


def init_params(cfg: ModelConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 10)
    dm = cfg.d_model
    p: Params = {}
    if cfg.family == "vit":
        pdim = cfg.patch_size * cfg.patch_size * 3
        n_patches = (cfg.image_size // cfg.patch_size) ** 2
        p["patch_embed"] = L.dense_init(ks[0], pdim, dm, dtype)
        p["patch_bias"] = L.zeros((dm,), dtype)
        p["pos_embed"] = (jax.random.normal(ks[1], (n_patches, dm))
                          * 0.02).astype(dtype)
        p["layers"] = _stack(cfg, ks[2], cfg.n_layers, dtype, "enc")
        p["head"] = L.dense_init(ks[3], dm, cfg.n_classes, dtype)
        p["head_bias"] = L.zeros((cfg.n_classes,), dtype)
        p["local_head"] = L.dense_init(ks[4], dm, cfg.n_classes, dtype)
        p["local_head_bias"] = L.zeros((cfg.n_classes,), dtype)
    elif cfg.is_encdec:
        p["frame_proj"] = L.dense_init(ks[0], dm, dm, dtype)
        p["embed"] = (jax.random.normal(ks[1], (cfg.padded_vocab, dm))
                      * 0.02).astype(dtype)
        p["dec_pos"] = (jax.random.normal(ks[5], (32768, dm))
                        * 0.02).astype(dtype)
        p["enc_layers"] = _stack(cfg, ks[2], cfg.n_enc_layers, dtype, "enc")
        p["dec_layers"] = _stack(cfg, ks[3], cfg.n_layers, dtype, "dec")
        p["enc_norm"] = L.norm_params(cfg, dm, dtype)
        p["dec_norm"] = L.norm_params(cfg, dm, dtype)
        p["local_head"] = L.dense_init(ks[4], dm, cfg.padded_vocab, dtype)
    else:
        p["embed"] = (jax.random.normal(ks[0], (cfg.padded_vocab, dm))
                      * 0.02).astype(dtype)
        if cfg.family == "vlm":
            p["vision_proj"] = L.dense_init(ks[3], dm, dm, dtype)
        p["layers"] = _stack(cfg, ks[1], cfg.n_layers, dtype, layer_role(cfg))
        p["final_norm"] = L.norm_params(cfg, dm, dtype)
        # NOTE: the global head is always untied here, even when the source
        # model ties embeddings — SuperSFL's client/server parameter split
        # puts the embedding on the CLIENT and the head on the SERVER, so a
        # tied head would leak client params into the server branch
        # (DESIGN.md §4).
        p["unembed"] = L.dense_init(ks[2], dm, cfg.padded_vocab, dtype)
        p["local_head"] = L.dense_init(ks[4], dm, cfg.padded_vocab, dtype)
    return p


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ------------------------------------------------------------- layer bodies

def _sinusoid(S: int, dm: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, dm, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / dm)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def _attn_block(cfg: ModelConfig, p, h, *, positions, causal, window,
                use_rope=True):
    """Returns (attn_out_projected, (k, v) post-rope for caching)."""
    x = L.apply_norm(cfg, h, p, "attn_norm")
    q, k, v = L.project_qkv(cfg, p["attn"], x, x)
    if use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas and q.shape[1] > 1 and causal:
        from repro.kernels.flash_attention import ops as FA
        out = FA.flash_attention(q, k, v, causal=causal, window=window)
    elif q.shape[1] >= L.ATTN_BLOCKWISE_THRESHOLD:
        q = _constrain_batch(cfg, q)
        k = _constrain_batch(cfg, k)
        v = _constrain_batch(cfg, v)
        out = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                    skip_masked_blocks=cfg.attn_block_skip)
    else:
        mask = L.make_attn_mask(positions, positions, causal=causal,
                                window=window)
        out = L.attention(q, k, v, mask=mask)
    B, S = out.shape[:2]
    return out.reshape(B, S, -1) @ p["attn"]["wo"], (k, v)


def _constrain_batch(cfg: ModelConfig, x):
    """Pin the leading (batch) axis to the data axes inside scans so GSPMD
    never falls back to replication (no-op when batch_shard_axes is empty or
    the batch doesn't divide the mesh extent)."""
    if not cfg.batch_shard_axes or x.ndim < 2:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(cfg.batch_shard_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _make_layer_fn(cfg: ModelConfig, role: str, *, positions, causal,
                   window, enc_out=None, emit: bool = False):
    """Returns body(carry=(h, aux), p_layer) -> ((h, aux), per-layer cache)."""
    use_rope = role in ("dense", "moe", "hybrid")

    def body(carry, p):
        h, aux = carry
        h = _constrain_batch(cfg, h)
        ys = None
        if role in ("dense", "moe", "enc", "dec"):
            out, kv = _attn_block(cfg, p, h, positions=positions,
                                  causal=causal, window=window,
                                  use_rope=use_rope)
            h = h + out
            if emit:
                ys = {"k": kv[0], "v": kv[1]}
        elif role == "ssm":
            x = L.apply_norm(cfg, h, p, "attn_norm")
            if emit:
                s, hf, conv = SSM.ssm_apply(cfg, p["ssm"], x,
                                            return_state=True)
                ys = {"ssm_h": hf, "ssm_conv": conv}
            else:
                s = SSM.ssm_apply(cfg, p["ssm"], x)
            h = h + s
        elif role == "hybrid":
            a, kv = _attn_block(cfg, p, h, positions=positions,
                                causal=causal, window=window, use_rope=True)
            x = L.apply_norm(cfg, h, p, "attn_norm")
            if emit:
                s, hf, conv = SSM.ssm_apply(cfg, p["ssm"], x,
                                            return_state=True)
                ys = {"k": kv[0], "v": kv[1], "ssm_h": hf, "ssm_conv": conv}
            else:
                s = SSM.ssm_apply(cfg, p["ssm"], x)
            h = h + p["branch_scale_attn"] * a + p["branch_scale_ssm"] * s
        if role == "dec":
            x = L.apply_norm(cfg, h, p, "cross_norm")
            q, k, v = L.project_qkv(cfg, p["cross"], x, enc_out)
            out = L.attention(q, k, v, mask=None)
            B, S = out.shape[:2]
            h = h + out.reshape(B, S, -1) @ p["cross"]["wo"]
            if emit:
                ys["cross_k"] = k
                ys["cross_v"] = v
        if role in ("dense", "enc", "dec", "hybrid"):
            x = L.apply_norm(cfg, h, p, "mlp_norm")
            h = h + L.mlp_apply(cfg, p["mlp"], x)
        elif role == "moe":
            x = L.apply_norm(cfg, h, p, "mlp_norm")
            y, a = MOE.moe_apply(cfg, p["moe"], x)
            h = h + y
            aux = aux + a
        return (h, aux), ys

    return body


def static_depth(d) -> bool:
    """True when ``d`` is a trace-time constant (Python/numpy int) rather
    than a runtime jax value (Array/Tracer). Static depths slice the stack
    at trace time (one jit program per depth); runtime depths take the
    masked scan over the full stack (one program for every depth)."""
    return isinstance(d, (int, np.integer))


def run_stack(cfg: ModelConfig, stack: Params, h, *, role: str, positions,
              causal: bool, window: int = 0, enc_out=None,
              emit: bool = False, length=None, mode: str = "prefix"):
    """Scan the layer stack over ``h``.

    ``length=None`` (the static path) scans every row of ``stack`` — the
    caller sliced the depth window out at trace time. With a runtime
    ``length`` the scan always covers the *full* stack and each layer body
    applies only where its index is inside the depth window
    (``mode="prefix"``: ``i < length``; ``mode="suffix"``: ``i >= length``)
    — the carry passes through inactive layers unchanged via ``jnp.where``,
    so active-layer math is op-for-op identical to the static slice and
    the gradient w.r.t. an inactive layer's parameters is exactly zero
    (``where``'s vjp routes the cotangent only to the selected branch).
    """
    body = _make_layer_fn(cfg, role, positions=positions, causal=causal,
                          window=window, enc_out=enc_out, emit=emit)
    if length is None:
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), ys = jax.lax.scan(body, (h, jnp.float32(0.0)), stack)
        if emit:
            return h, aux, ys
        return h, aux
    assert not emit, "runtime-depth run_stack does not support emit/decode"
    assert mode in ("prefix", "suffix"), mode
    L_rows = jax.tree.leaves(stack)[0].shape[0]

    def masked(carry, xs):
        p, i = xs
        (h2, aux2), ys = body(carry, p)
        active = (i < length) if mode == "prefix" else (i >= length)
        h0, aux0 = carry
        return (jnp.where(active, h2, h0), jnp.where(active, aux2, aux0)), ys

    if cfg.remat:
        masked = jax.checkpoint(masked)
    (h, aux), _ = jax.lax.scan(masked, (h, jnp.float32(0.0)),
                               (stack, jnp.arange(L_rows)))
    return h, aux


# ---------------------------------------------------------------- embeddings

def embed_inputs(cfg: ModelConfig, params: Params, batch) -> Tuple[Any, Any]:
    """Returns (h [B,S,dm], positions [B,S])."""
    dm = cfg.d_model
    if cfg.family == "vit":
        img = batch["images"]
        B, Hh, Ww, C = img.shape
        ps = cfg.patch_size
        patches = img.reshape(B, Hh // ps, ps, Ww // ps, ps, C)
        patches = patches.transpose(0, 1, 3, 2, 4, 5).reshape(
            B, (Hh // ps) * (Ww // ps), ps * ps * C)
        h = patches.astype(params["patch_embed"].dtype) @ params["patch_embed"]
        h = h + params["patch_bias"] + params["pos_embed"][None]
        pos = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
        return h, pos
    if cfg.is_encdec:
        h = batch["frames"] @ params["frame_proj"]
        h = h + _sinusoid(h.shape[1], dm, h.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
        return h, pos
    tok_emb = params["embed"][batch["tokens"]] * math.sqrt(dm)
    if cfg.family == "vlm":
        pe = batch["patches"].astype(tok_emb.dtype) @ params["vision_proj"]
        h = jnp.concatenate([pe, tok_emb], axis=1)
    else:
        h = tok_emb
    pos = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    return h, pos


def _head_logits(cfg: ModelConfig, params: Params, h):
    if cfg.family == "vit":
        pooled = jnp.mean(h, axis=1)
        return pooled @ params["head"] + params["head_bias"]
    if "unembed" in params:
        return h @ params["unembed"]
    return h @ params["embed"].T  # enc-dec decoder head stays tied


# --------------------------------------------------------- SuperSFL surfaces

def prefix_apply(cfg: ModelConfig, params: Params, batch, d: int):
    """Client-side forward through the first ``d`` layers -> smashed data.

    ``d`` may be a Python int (trace-time slice — one jit program per
    depth) or a jax scalar (masked full-stack scan — one program for all
    depths; see :func:`run_stack`)."""
    h, pos = embed_inputs(cfg, params, batch)
    role = layer_role(cfg)
    stack_name = "enc_layers" if cfg.is_encdec else "layers"
    causal = role in ("dense", "moe", "hybrid")
    if static_depth(d):
        stack = jax.tree.map(lambda x: x[:d], params[stack_name])
        return run_stack(cfg, stack, h, role=role, positions=pos,
                         causal=causal, window=cfg.sliding_window)
    return run_stack(cfg, params[stack_name], h, role=role, positions=pos,
                     causal=causal, window=cfg.sliding_window,
                     length=d, mode="prefix")


def client_apply(cfg: ModelConfig, client_params: Params, batch,
                 length=None):
    """Forward an already-split client view -> smashed z.

    The width-slice path: pass ``supernet.width_cfg(cfg, w)`` as ``cfg`` and
    a ``split_params(..., width=w)`` client tree, and the layer bodies
    reshape by the sliced head/ff dims while the residual stream (and hence
    z) stays full ``d_model``.

    ``length=None`` expects the depth slice already taken (rows ``[:d]``);
    a runtime ``length`` expects the FULL ``L``-row stack and masks rows
    ``>= length`` out of the scan.
    """
    h, pos = embed_inputs(cfg, client_params, batch)
    role = layer_role(cfg)
    stack_name = "enc_layers" if cfg.is_encdec else "layers"
    causal = role in ("dense", "moe", "hybrid")
    return run_stack(cfg, client_params[stack_name], h, role=role,
                     positions=pos, causal=causal,
                     window=cfg.sliding_window, length=length,
                     mode="prefix")


def local_logits(cfg: ModelConfig, params: Params, z):
    """Fault-tolerant lightweight client head on smashed data."""
    if cfg.family == "vit":
        pooled = jnp.mean(z, axis=1)
        return pooled @ params["local_head"] + params["local_head_bias"]
    if cfg.is_encdec:
        pooled = jnp.mean(z, axis=1)          # unigram head over frames
        return pooled @ params["local_head"]
    return z @ params["local_head"]


def _label_fields(cfg: ModelConfig, batch):
    if cfg.family == "vit":
        return batch["label"], None
    return batch["labels"], batch.get("valid")


def local_loss(cfg: ModelConfig, params: Params, z, batch):
    logits = local_logits(cfg, params, z)
    labels, valid = _label_fields(cfg, batch)
    if cfg.family == "vit":
        return L.softmax_xent(logits, labels)
    if cfg.is_encdec:
        # unigram proxy: pooled logits predict each decoder label position
        Bl, S = labels.shape
        logits = jnp.broadcast_to(logits[:, None, :],
                                  (Bl, S, logits.shape[-1]))
        return L.softmax_xent(logits, labels, valid=valid, vocab=cfg.vocab)
    if cfg.family == "vlm":
        npatch = cfg.n_patches
        logits = logits[:, npatch:, :]
    return L.softmax_xent(logits, labels, valid=valid, vocab=cfg.vocab)


def suffix_apply(cfg: ModelConfig, params: Params, z, batch, d: int):
    """Server-side forward from smashed data to final logits.

    Static ``d`` slices rows ``[d:]`` at trace time; a runtime ``d``
    forwards the FULL stack and masks rows ``< d`` out of the scan."""
    sname = "enc_layers" if cfg.is_encdec else "layers"
    if static_depth(d):
        sp = dict(params)
        sp[sname] = jax.tree.map(lambda x: x[d:], params[sname])
        return server_apply(cfg, sp, z, batch)
    return server_apply(cfg, params, z, batch, length=d)


def server_apply(cfg: ModelConfig, server_params: Params, z, batch,
                 length=None):
    """Like ``suffix_apply``, but on an already-split server view whose
    stack holds only the suffix layers (what ``split_params`` returns) —
    the form TPGF's split-gradient path differentiates directly.

    ``length=None`` expects a pre-sliced suffix stack; a runtime ``length``
    expects the FULL ``L``-row split stack and masks rows ``< length``.
    For enc-dec only the split stack (``enc_layers``) is masked — the
    decoder always runs every row."""
    role = layer_role(cfg)
    if cfg.is_encdec:
        pos = jnp.broadcast_to(jnp.arange(z.shape[1]), z.shape[:2])
        enc_out, aux = run_stack(cfg, server_params["enc_layers"], z,
                                 role="enc", positions=pos, causal=False,
                                 length=length, mode="suffix")
        enc_out = L.apply_norm(cfg, enc_out, {
            f"attn_norm_{k}": v
            for k, v in server_params["enc_norm"].items()},
            "attn_norm")
        tok = batch["tokens"]
        hd = server_params["embed"][tok] * math.sqrt(cfg.d_model)
        hd = hd + server_params["dec_pos"][:tok.shape[1]][None]
        dpos = jnp.broadcast_to(jnp.arange(tok.shape[1]), tok.shape)
        hd, aux2 = run_stack(cfg, server_params["dec_layers"], hd,
                             role="dec", positions=dpos, causal=True,
                             enc_out=enc_out)
        hd = L.apply_norm(cfg, hd, {
            f"attn_norm_{k}": v
            for k, v in server_params["dec_norm"].items()},
            "attn_norm")
        return _head_logits(cfg, server_params, hd), aux + aux2
    pos = jnp.broadcast_to(jnp.arange(z.shape[1]), z.shape[:2])
    causal = role in ("dense", "moe", "hybrid")
    h, aux = run_stack(cfg, server_params["layers"], z, role=role,
                       positions=pos, causal=causal,
                       window=cfg.sliding_window, length=length,
                       mode="suffix")
    if cfg.family == "vit":
        return _head_logits(cfg, server_params, h), aux
    h = L.apply_norm(cfg, h, {
        f"attn_norm_{k}": v for k, v in server_params["final_norm"].items()},
        "attn_norm")
    return _head_logits(cfg, server_params, h), aux


def _server_xent(cfg: ModelConfig, logits, aux, batch):
    labels, valid = _label_fields(cfg, batch)
    if cfg.family == "vit":
        return L.softmax_xent(logits, labels) + cfg.router_aux_coef * aux
    if cfg.family == "vlm":
        logits = logits[:, cfg.n_patches:, :]
    return (L.softmax_xent(logits, labels, valid=valid, vocab=cfg.vocab)
            + cfg.router_aux_coef * aux)


def server_loss(cfg: ModelConfig, params: Params, z, batch, d: int):
    logits, aux = suffix_apply(cfg, params, z, batch, d)
    return _server_xent(cfg, logits, aux, batch)


def server_split_loss(cfg: ModelConfig, server_params: Params, z, batch,
                      length=None):
    """``server_loss`` over an already-split server view (no depth slice);
    a runtime ``length`` takes the full-stack masked-suffix path."""
    logits, aux = server_apply(cfg, server_params, z, batch, length=length)
    return _server_xent(cfg, logits, aux, batch)


def full_loss(cfg: ModelConfig, params: Params, batch):
    """Plain end-to-end loss (FedAvg / centralized baseline)."""
    z, aux = prefix_apply(cfg, params, batch, cfg.resolved_split_depth)
    ls = server_loss(cfg, params, z, batch, cfg.resolved_split_depth)
    return ls + cfg.router_aux_coef * aux


# -------------------------------------------------------------- dummy inputs

def make_dummy_batch(cfg: ModelConfig, shape: InputShape, rng):
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(rng)
    if cfg.family == "vit":
        return {"images": jax.random.normal(
                    k1, (B, cfg.image_size, cfg.image_size, 3), dtype),
                "label": jax.random.randint(k2, (B,), 0, cfg.n_classes)}
    if cfg.is_encdec:
        k3 = jax.random.fold_in(k2, 1)   # labels need their own stream
        return {"frames": jax.random.normal(
                    k1, (B, cfg.enc_frames, cfg.d_model), dtype),
                "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        S_text = S - cfg.n_patches
        k3 = jax.random.fold_in(k2, 1)
        return {"patches": jax.random.normal(
                    k1, (B, cfg.n_patches, cfg.d_model), dtype),
                "tokens": jax.random.randint(k2, (B, S_text), 0, cfg.vocab),
                "labels": jax.random.randint(k3, (B, S_text), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab)}

from repro.models import model, decode, layers, moe, ssm  # noqa: F401

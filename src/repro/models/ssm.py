"""Mamba2-style SSD (state-space duality) blocks in pure JAX.

Chunked SSD formulation (arXiv:2405.21060): quadratic attention-like math
within chunks, linear recurrence across chunks. The across-chunk scan is a
``lax.scan`` over n_chunks, which keeps the HLO small for 64-layer stacks.

The per-chunk einsum block is also the compute hot-spot mirrored by the
Pallas kernel in ``repro/kernels/ssd_scan.py`` (this file is the oracle's
basis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, zeros, rmsnorm

DEFAULT_CHUNK = 256


def ssm_params(cfg: ModelConfig, key, dtype):
    dm = cfg.d_model
    din = cfg.ssm_d_inner
    nh = cfg.ssm_n_heads
    st = cfg.ssm_state
    k = cfg.ssm_conv_dim
    ks = jax.random.split(key, 8)
    return {
        "w_x": dense_init(ks[0], dm, din, dtype),
        "w_z": dense_init(ks[1], dm, din, dtype),
        "w_B": dense_init(ks[2], dm, st, dtype),
        "w_C": dense_init(ks[3], dm, st, dtype),
        "w_dt": dense_init(ks[4], dm, nh, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01))).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "conv_w": (jax.random.normal(ks[5], (k, din)) * 0.1).astype(dtype),
        "conv_b": zeros((din,), dtype),
        "gate_norm_scale": zeros((din,), dtype),
        "w_out": dense_init(ks[6], din, dm, dtype),
    }


def causal_conv(x, w, b):
    """Depthwise causal conv. x [B,S,D]; w [k,D]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def ssd_chunked(x, dt, A, B, C, *, chunk: int = DEFAULT_CHUNK, h0=None):
    """Chunked SSD scan.

    x: [Bt, S, nh, hd] (already dt-scaled NOT applied; we apply here)
    dt: [Bt, S, nh] (post-softplus), A: [nh] (negative), B,C: [Bt, S, st]
    h0: optional initial state [Bt, nh, hd, st].
    Returns y [Bt, S, nh, hd], h_final [Bt, nh, hd, st].
    """
    Bt, S, nh, hd = x.shape
    st = B.shape[-1]
    if S % chunk != 0:
        chunk = S  # fall back to a single chunk for short sequences
    nc = S // chunk

    # One sequential lax.scan over chunks: peak intermediate is ONE chunk's
    # [Bt, cl, cl, nh] decay matrix instead of all nc at once — this is what
    # keeps 32k-500k sequences lowerable (the Pallas ssd_scan kernel is the
    # TPU-native version of exactly this loop).
    xc = jnp.moveaxis(x.reshape(Bt, nc, chunk, nh, hd), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bt, nc, chunk, nh), 1, 0)
    Bc = jnp.moveaxis(B.reshape(Bt, nc, chunk, st), 1, 0)
    Cc = jnp.moveaxis(C.reshape(Bt, nc, chunk, st), 1, 0)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(h, inp):
        xk, dtk, Bk, Ck = inp                        # [Bt,cl,...]
        dA = dtk * A                                 # [Bt,cl,nh]
        s = jnp.cumsum(dA, axis=1)
        u = xk * dtk[..., None]                      # [Bt,cl,nh,hd]
        CB = jnp.einsum("bis,bjs->bij", Ck, Bk)      # [Bt,cl,cl]
        Lm = jnp.exp(s[:, :, None, :] - s[:, None, :, :])  # [Bt,i,j,nh]
        W = jnp.where(tri[None, :, :, None], CB[..., None] * Lm, 0.0)
        y = jnp.einsum("bijh,bjhd->bihd", W, u)      # intra-chunk
        y = y + jnp.einsum("bis,bih,bhds->bihd", Ck, jnp.exp(s), h)
        decay_end = jnp.exp(s[:, -1:, :] - s)        # [Bt,cl,nh]
        h_chunk = jnp.einsum("bjh,bjs,bjhd->bhds", decay_end, Bk, u)
        h_new = h * jnp.exp(s[:, -1, :])[:, :, None, None] + h_chunk
        return h_new, y

    if h0 is None:
        h0 = jnp.zeros((Bt, nh, hd, st), x.dtype)
    h_final, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, S, nh, hd)
    return y, h_final


def ssm_apply(cfg: ModelConfig, p, x_in, *, chunk: int = DEFAULT_CHUNK,
              return_state: bool = False):
    """Full Mamba2 mixer on [B,S,dm] -> [B,S,dm] (training/prefill path)."""
    nh, hd = cfg.ssm_n_heads, cfg.ssm_head_dim
    xs_raw = x_in @ p["w_x"]
    z = x_in @ p["w_z"]
    xs = jax.nn.silu(causal_conv(xs_raw, p["conv_w"], p["conv_b"]))
    B = x_in @ p["w_B"]
    C = x_in @ p["w_C"]
    dt = jax.nn.softplus((x_in @ p["w_dt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Bsz, S = x_in.shape[:2]
    xh = xs.reshape(Bsz, S, nh, hd)
    y, h_final = ssd_chunked(xh.astype(jnp.float32), dt.astype(jnp.float32),
                             A, B.astype(jnp.float32), C.astype(jnp.float32),
                             chunk=chunk)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, nh * hd).astype(x_in.dtype)
    y = rmsnorm(y, p["gate_norm_scale"]) * jax.nn.silu(z)
    out = y @ p["w_out"]
    if return_state:
        k = cfg.ssm_conv_dim
        conv_tail = xs_raw[:, S - (k - 1):, :]
        return out, h_final, conv_tail
    return out


def ssm_decode_init(cfg: ModelConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.ssm_d_inner),
                          dtype),
    }


def ssm_decode_step(cfg: ModelConfig, p, x_in, state):
    """x_in [B,1,dm]; state from ssm_decode_init. Returns (y [B,1,dm], state)."""
    nh, hd = cfg.ssm_n_heads, cfg.ssm_head_dim
    x = x_in[:, 0, :]
    xs = x @ p["w_x"]                                # [B,din]
    z = x @ p["w_z"]
    window = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xs = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]
    B = (x @ p["w_B"]).astype(jnp.float32)           # [B,st]
    C = (x @ p["w_C"]).astype(jnp.float32)
    dt = jax.nn.softplus((x @ p["w_dt"]) + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)
    a = jnp.exp(dt * A)                              # [B,nh]
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhd,bs->bhds", dt, xh, B)
    y = jnp.einsum("bs,bhds->bhd", C, h) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(x.shape[0], nh * hd).astype(x_in.dtype)
    y = rmsnorm(y, p["gate_norm_scale"]) * jax.nn.silu(z)
    y = (y @ p["w_out"])[:, None, :]
    return y, {"h": h, "conv": new_conv}

"""Top-k token-choice MoE (Mixtral/Grok style) with load-balance aux loss."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def moe_params(cfg: ModelConfig, key, dtype):
    dm, dff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    down_scale = 0.02 / math.sqrt(2 * cfg.n_layers)

    def einit(k, i, o, scale=0.02):
        return (jax.random.normal(k, (E, i, o)) * scale).astype(dtype)

    return {
        "router": dense_init(ks[0], dm, E, dtype),
        "w_gate": einit(ks[1], dm, dff),
        "w_up": einit(ks[2], dm, dff),
        "w_down": einit(ks[3], dff, dm, down_scale),
    }


MOE_TOKEN_CHUNK = 4096


def _moe_tokens_dense(cfg: ModelConfig, p, xt):
    """Dense dispatch over a flat token chunk xt [T, dm] -> (y, f_e, P_e).

    Every expert computes every token, masked by renormalized top-k router
    weights: zero all-to-all / sort, at the cost of E/k redundant FLOPs —
    the paper-agnostic baseline; the §Perf expert-dispatch hillclimb
    replaces it with capacity-based gather dispatch.
    """
    E, k = cfg.n_experts, cfg.top_k
    logits = (xt @ p["router"]).astype(jnp.float32)        # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                   # [T,k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # [T,k,E]
    combine = jnp.einsum("tke,tk->te", onehot, topv)

    g = jnp.einsum("td,edf->etf", xt, p["w_gate"])
    u = jnp.einsum("td,edf->etf", xt, p["w_up"])
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("etf,efd->etd", h, p["w_down"])
    y = jnp.einsum("etd,te->td", y_e, combine.astype(xt.dtype))

    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)        # [E]
    P_e = jnp.mean(probs, axis=0)                          # [E]
    return y, f_e, P_e


def _moe_tokens_gather(cfg: ModelConfig, p, xt):
    """Capacity-based top-k gather dispatch (GShard-style, sort-free).

    Each expert processes a fixed-capacity slice gathered by ranking tokens
    by router probability; overflow tokens are dropped for that expert
    (standard capacity-factor semantics). FLOPs = k/E of dense dispatch.
    """
    E, k = cfg.n_experts, cfg.top_k
    T = xt.shape[0]
    cap = min(max(int(cfg.moe_capacity_factor * T * k / E), 1), T)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    gate = jnp.zeros((T, E), jnp.float32)
    gate = jnp.einsum("tke,tk->te", jax.nn.one_hot(topi, E), topv)

    # per expert: indices of its top-`cap` tokens by gate weight
    gval, gidx = jax.lax.top_k(gate.T, cap)                # [E,cap]
    sel = jnp.take(xt, gidx.reshape(-1), axis=0).reshape(E, cap, -1)
    g = jnp.einsum("ecd,edf->ecf", sel, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", sel, p["w_up"])
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # [E,cap,dm]
    w_e = jnp.where(gval > 0, gval, 0.0).astype(xt.dtype)  # dropped -> 0
    y = jnp.zeros_like(xt)
    y = y.at[gidx.reshape(-1)].add(
        (y_e * w_e[..., None]).reshape(E * cap, -1))

    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    P_e = jnp.mean(probs, axis=0)
    return y, f_e, P_e


def moe_apply(cfg: ModelConfig, p, x):
    """x: [B, S, dm] -> (y, aux_loss).

    Tokens are processed in fixed-size chunks under a sequential lax.scan so
    the expert intermediate is [E, chunk, d_ff] instead of [E, B*S, d_ff] —
    required for 32k prefill shapes. Aux loss is the standard Switch
    load-balance term E * sum_e f_e * P_e.
    """
    B, S, dm = x.shape
    E = cfg.n_experts
    xt = x.reshape(B * S, dm)
    T = B * S
    c = min(MOE_TOKEN_CHUNK, T)
    fn = (_moe_tokens_gather if cfg.moe_dispatch == "gather"
          else _moe_tokens_dense)
    if T % c != 0 or T == c:
        y, f_e, P_e = fn(cfg, p, xt)
    else:
        xc = xt.reshape(T // c, c, dm)

        def step(_, xk):
            return None, fn(cfg, p, xk)

        _, (ys, f_es, P_es) = jax.lax.scan(step, None, xc)
        y = ys.reshape(T, dm)
        f_e, P_e = jnp.mean(f_es, axis=0), jnp.mean(P_es, axis=0)
    aux = E * jnp.sum(f_e * P_e) / cfg.top_k
    return y.reshape(B, S, dm), aux

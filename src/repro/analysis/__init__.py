"""Static analysis for the fleet engine (``repro.analysis.fleetlint``).

The runtime half of the correctness substrate — the ``checkify``-based
sanitizer mode — lives with the kernels it wraps
(``repro.federated.bucketing.FleetKernel.sanitized`` and
``Engine(sanitize=True)``); this package holds the *static* half, which
must stay importable without jax (CI runs it before installing anything).
"""
from repro.analysis.fleetlint import Finding, lint_paths, lint_source  # noqa: F401

"""fleetlint — kernel-contract static analysis for the fleet engine.

Five PRs of engine growth (bucketed kernels, shard_map SPMD, FedBuff)
piled up invariants that nothing enforced: padded slots must be masked out
of every cross-slot reduction, psum axis names must flow from the declared
fleet axes, the round path must stay deterministic and host-sync-free.
Violations are silent-corruption bugs — a wrongly-averaged padded slot
looks like slow drift, not a crash — so this module checks them *at the
AST level*, before a kernel ever compiles.

Rules (each has a code, a message, and a fix-it):

  FL001  no host sync inside compiled kernel code: ``float()`` / ``bool()``
         / ``.item()`` / ``np.asarray()`` / ``jax.device_get()`` on traced
         values inside ``register_kernel`` impls or ``lax.scan`` bodies.
  FL002  no raw cross-slot reductions in fleet modules: ``jnp.sum`` /
         ``jnp.mean`` over axis 0 must be ``jnp.where``-guarded or go
         through ``bucketing.slot_sum`` / ``masked_slot_mean``; bare
         ``jnp.any`` / ``jnp.all`` must go through ``freeze_gate``.
         A raw reduction silently averages padded slots into the result.
  FL003  psum/pmean axis names must flow from the kernel's ``axis_name``
         parameter (never string literals), parameterized
         ``register_kernel`` kernels must declare ``specs=``, and the
         specs function's in/out PartitionSpec tuples must cover every
         kernel array argument and output (the pspec-coverage contract of
         ``launch.sharding.slot_pspec``).
  FL004  determinism on the round path: no ``time.time``-family calls, no
         global ``np.random.*`` state, no unseeded ``default_rng()``.
         Every RNG stream must be seeded and checkpointable (the
         ``Engine.save`` stream contract).
  FL005  Strategy implementations must match the ``Strategy`` protocol
         hook signatures — including the 3-arg vs ``ids=`` ``comm_cost``
         probe the engine dispatches on.

Suppression: append ``# fleetlint: disable=FL002`` (comma-separate for
several codes) to the offending line, followed by a one-line
justification. Scope pragmas for files outside the repo layout (fixture
corpora): a ``# fleetlint: scope=fleet`` comment anywhere in a file marks
it as fleet/round-path scope for FL002/FL004.

The module is stdlib-only (``ast`` + ``re``) so CI can run it before
installing anything: ``python tools/fleetlint.py`` or, installed,
``repro-lint``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------- rules

RULES: Dict[str, str] = {
    "FL001": "no host sync inside register_kernel impls / lax.scan bodies",
    "FL002": "no raw cross-slot reductions in fleet modules",
    "FL003": "psum axis names and kernel pspec coverage",
    "FL004": "nondeterminism ban on the round path",
    "FL005": "Strategy protocol hook signatures",
}

_SUPPRESS_RE = re.compile(r"#\s*fleetlint:\s*disable=((?:FL\d{3})(?:\s*,\s*FL\d{3})*)")
_SCOPE_RE = re.compile(r"#\s*fleetlint:\s*scope=fleet\b")

# time-source calls banned on the round path (FL004)
_TIME_CALLS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
               "monotonic", "monotonic_ns", "now", "utcnow", "today"}
# np.random attributes that are fine on the round path (seeded, explicit
# generator objects — everything else is the hidden global stream)
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "BitGenerator"}

# Strategy protocol hooks: name -> (required positional names after self,
# allowed optional extras — every extra must carry a default)
_PROTOCOL_HOOKS: Dict[str, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {
    "init_round": (("engine", "ctx"), ()),
    "cohort_step": (("engine", "ctx", "ws", "d", "ids"), ()),
    "fold_server": (("engine", "ws", "d", "ids", "res"), ()),
    "aggregate": (("engine", "ws"), ()),
    "cohorts": (("engine", "ctx"), ()),
    "fixed_depth": (("cfg",), ()),
    "prepare_fleet": (("cfg", "fleet"), ("device_model",)),
    "participation_process": (("cfg", "n_clients", "seed"), ()),
    "comm_cost": (("engine", "d", "available"), ("ids",)),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str
    line: int
    col: int
    message: str
    fixit: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"{self.message}\n        fix: {self.fixit}")


# ----------------------------------------------------------------- utilities

def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.sum' / 'jax.lax.psum' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const(node: ast.AST):
    return node.value if isinstance(node, ast.Constant) else _NOT_CONST


_NOT_CONST = object()


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _is_where_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    d = _dotted(node.func) or ""
    return d.split(".")[-1] == "where"


class _Lines:
    """Per-line suppression sets + the file-level scope pragma."""

    def __init__(self, source: str):
        self.suppress: Dict[int, Set[str]] = {}
        self.fleet_scope = False
        for n, line in enumerate(source.splitlines(), 1):
            m = _SUPPRESS_RE.search(line)
            if m:
                self.suppress[n] = {c.strip() for c in m.group(1).split(",")}
            if _SCOPE_RE.search(line):
                self.fleet_scope = True

    def allows(self, code: str, line: int) -> bool:
        return code not in self.suppress.get(line, ())


# ----------------------------------------------------------- module analysis

class _Module:
    def __init__(self, path: Path, source: str, rel: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.lines = _Lines(source)
        posix = Path(rel).as_posix()
        # round-path scope (FL002/FL004): the federated engine, the core
        # numerics it calls, and the data pipeline feeding the batch stream
        self.fleet_scope = self.lines.fleet_scope or any(
            f"/{pkg}/" in f"/{posix}" or posix.startswith(f"{pkg}/")
            for pkg in ("federated", "core", "data"))
        self.kernel_fns = self._kernel_functions()
        self.scan_bodies = self._scan_body_functions()

    # -- what counts as compiled-kernel code ---------------------------------
    def _kernel_functions(self) -> List[ast.FunctionDef]:
        """Functions decorated with (any spelling of) register_kernel."""
        out = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    d = _dotted(target) or ""
                    if d.split(".")[-1] == "register_kernel":
                        out.append(node)
                        break
        return out

    def _scan_body_functions(self) -> List[ast.AST]:
        """Function defs (or lambdas) passed as the first argument of a
        ``lax.scan`` call anywhere in the module."""
        names: Set[str] = set()
        lambdas: List[ast.AST] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            d = _dotted(node.func) or ""
            parts = d.split(".")
            if parts[-1] != "scan" or ("lax" not in parts and "jax" not in parts):
                continue
            first = node.args[0]
            if isinstance(first, ast.Name):
                names.add(first.id)
            elif isinstance(first, ast.Lambda):
                lambdas.append(first)
        defs = [n for n in ast.walk(self.tree)
                if isinstance(n, ast.FunctionDef) and n.name in names]
        return defs + lambdas


def _walk_no_strings(root: ast.AST):
    yield from ast.walk(root)


# ------------------------------------------------------------------ FL001

def _check_fl001(mod: _Module, add) -> None:
    roots: List[ast.AST] = list(mod.kernel_fns) + list(mod.scan_bodies)
    seen: Set[int] = set()
    for root in roots:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            seen.add(id(node))
            d = _dotted(node.func) or ""
            parts = d.split(".")
            bad = None
            if d in ("float", "bool") and node.args:
                bad = (f"{d}() forces a device->host sync on a traced value",
                       "keep values on device; cast with jnp/astype, or "
                       "branch with jnp.where instead of python truthiness")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ("item", "tolist", "block_until_ready") \
                    and not node.args:
                bad = (f".{node.func.attr}() forces a device->host sync",
                       "return the array and sync once per round in "
                       "_finish_aggregation (the one-host-sync contract)")
            elif parts[0] in ("np", "numpy") and \
                    parts[-1] in ("asarray", "array", "copy"):
                bad = (f"{d}() materializes a traced value on the host",
                       "use jnp.asarray outside the kernel, or pass the "
                       "array in as a kernel argument")
            elif parts[-1] == "device_get":
                bad = (f"{d}() inside compiled kernel code",
                       "host syncs belong after the kernel returns — the "
                       "round syncs exactly once, in _finish_aggregation")
            if bad:
                add("FL001", node, bad[0] + " inside a "
                    "register_kernel impl / lax.scan body", bad[1])


# ------------------------------------------------------------------ FL002

def _reduces_axis0(call: ast.Call) -> bool:
    axis = _kw(call, "axis")
    if axis is None and len(call.args) >= 2:
        axis = call.args[1]
    return axis is not None and _const(axis) == 0


def _check_fl002(mod: _Module, add) -> None:
    if not mod.fleet_scope:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func) or ""
        parts = d.split(".")
        if parts[0] != "jnp":
            continue
        if parts[-1] in ("sum", "mean") and _reduces_axis0(node):
            if node.args and _is_where_call(node.args[0]):
                continue   # masked reduction: padded slots zeroed explicitly
            add("FL002", node,
                f"raw jnp.{parts[-1]}(axis=0) over the slot axis — padded "
                "bucket slots would pollute the reduction",
                "route through bucketing.slot_sum / masked_slot_mean (they "
                "mask and psum over the fleet axis), or zero padded slots "
                "with jnp.where(valid_row, x, 0) first")
        elif parts[-1] in ("any", "all"):
            axis = _kw(node, "axis")
            if axis is None and len(node.args) >= 2:
                axis = node.args[1]
            if axis is None or _const(axis) == 0:
                add("FL002", node,
                    f"raw jnp.{parts[-1]}() across slots — a padded slot "
                    "must never flip a cross-slot gate",
                    "use bucketing.freeze_gate(avail, valid, axis_name): it "
                    "masks padded slots and psums across fleet shards")


# ------------------------------------------------------------------ FL003

def _register_kernel_calls(mod: _Module):
    """(call, decorated_fn) for parameterized @register_kernel(...) uses."""
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    d = _dotted(dec.func) or ""
                    if d.split(".")[-1] == "register_kernel":
                        yield dec, node


def _tuple_len(node: ast.AST, assigns: Dict[str, ast.AST]) -> Optional[int]:
    if isinstance(node, ast.Name) and node.id in assigns:
        node = assigns[node.id]
    if isinstance(node, ast.Tuple):
        return len(node.elts)
    return None


def _specs_tuple_lens(fn: ast.FunctionDef) -> Tuple[Optional[int], Optional[int]]:
    """(len(in_specs), len(out_specs)) from a specs function, when its
    return resolves to tuple literals (directly or via simple assignment)."""
    assigns: Dict[str, ast.AST] = {}
    ret: Optional[ast.Return] = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            assigns[node.targets[0].id] = node.value
        elif isinstance(node, ast.Return):
            ret = node
    if ret is None or not isinstance(ret.value, ast.Tuple) or \
            len(ret.value.elts) != 2:
        return None, None
    i, o = ret.value.elts
    return _tuple_len(i, assigns), _tuple_len(o, assigns)


def _kernel_return_len(fn: ast.FunctionDef) -> Optional[int]:
    for stmt in reversed(fn.body):
        if isinstance(stmt, ast.Return):
            if isinstance(stmt.value, ast.Tuple):
                return len(stmt.value.elts)
            return None if stmt.value is None else 1
    return None


def _check_fl003(mod: _Module, add) -> None:
    # (a) literal psum/pmean axis names anywhere
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func) or ""
        parts = d.split(".")
        if parts[-1] not in ("psum", "pmean", "pmax", "pmin", "all_gather") \
                or "lax" not in parts:
            continue
        axis = node.args[1] if len(node.args) >= 2 else _kw(node, "axis_name")
        literal = isinstance(axis, ast.Constant) and \
            isinstance(axis.value, str)
        if isinstance(axis, (ast.Tuple, ast.List)):
            literal = any(isinstance(e, ast.Constant) and
                          isinstance(e.value, str) for e in axis.elts)
        if literal:
            add("FL003", node,
                f"{parts[-1]} over a hard-coded axis name — it will "
                "desync from the fleet mesh declared by launch.sharding",
                "pass the kernel's axis_name parameter (bound by "
                "FleetKernel to launch.sharding.fleet_axes(mesh)) instead "
                "of a string literal")
    # (b)+(c) parameterized kernels: specs declared, arities covered
    fndefs = {n.name: n for n in ast.walk(mod.tree)
              if isinstance(n, ast.FunctionDef)}
    for dec, fn in _register_kernel_calls(mod):
        specs = _kw(dec, "specs")
        if specs is None:
            add("FL003", dec,
                f"kernel {fn.name!r} registered without specs= — its "
                "outputs have no PartitionSpec coverage and cannot be "
                "shard_mapped",
                "declare a specs(axes, *arrays) -> (in_specs, out_specs) "
                "function built from launch.sharding.slot_pspec")
            continue
        n_static_node = _kw(dec, "n_static")
        n_static = _const(n_static_node) if n_static_node is not None else 4
        if not isinstance(n_static, int):
            continue
        arg_names = [a.arg for a in fn.args.args]
        n_arrays = len(arg_names) - n_static - \
            (1 if "axis_name" in arg_names else 0)
        if "axis_name" not in arg_names and not any(
                a.arg == "axis_name" for a in fn.args.kwonlyargs):
            add("FL003", fn,
                f"kernel {fn.name!r} has no axis_name parameter — its "
                "cross-slot reductions cannot span fleet shards",
                "add a trailing axis_name=None parameter and thread it "
                "into every slot_sum / masked_slot_mean / freeze_gate")
        if not isinstance(specs, ast.Name) or specs.id not in fndefs:
            continue   # specs built elsewhere; arity not statically checkable
        n_in, n_out = _specs_tuple_lens(fndefs[specs.id])
        if n_in is not None and n_in != n_arrays:
            add("FL003", fndefs[specs.id],
                f"specs for kernel {fn.name!r} cover {n_in} input args but "
                f"the kernel takes {n_arrays} array arguments",
                "give every non-static kernel argument a PartitionSpec "
                "(slot_pspec for slot-leading args, P() for replicated)")
        n_ret = _kernel_return_len(fn)
        if n_out is not None and n_ret is not None and n_out != n_ret:
            add("FL003", fndefs[specs.id],
                f"specs for kernel {fn.name!r} cover {n_out} outputs but "
                f"the kernel returns {n_ret} values",
                "every kernel output leaf needs pspec coverage — extend "
                "out_specs to match the kernel's return tuple")


# ------------------------------------------------------------------ FL004

def _check_fl004(mod: _Module, add) -> None:
    if not mod.fleet_scope:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func) or ""
        parts = d.split(".")
        if parts[0] in ("time", "datetime") and parts[-1] in _TIME_CALLS:
            add("FL004", node,
                f"{d}() on the round path — wall-clock time makes rounds "
                "non-reproducible and breaks checkpoint-exact resume",
                "derive schedules from state.round_idx; wall-clock timing "
                "belongs in benchmarks/launch, not federated/ or core/")
        elif len(parts) >= 2 and parts[0] in ("np", "numpy") \
                and parts[-2] == "random" and parts[-1] not in _NP_RANDOM_OK:
            add("FL004", node,
                f"{d}() uses the hidden global numpy stream — it cannot be "
                "saved by Engine.save, so resume is not bit-identical",
                "draw from an explicit seeded np.random.default_rng(seed) "
                "stream wired into the checkpoint (the RNG-stream "
                "contract in federated.engine)")
        elif parts[-1] == "default_rng" and not node.args \
                and not node.keywords:
            add("FL004", node,
                "unseeded default_rng() on the round path — the stream "
                "cannot be reproduced from the construction seed",
                "pass an explicit seed with a fixed offset from the "
                "engine seed (see the RNG-stream contract), and persist "
                "the stream position in Engine.save")
        elif parts[0] == "random" and len(parts) == 2:
            add("FL004", node,
                f"stdlib {d}() global stream on the round path",
                "use a seeded np.random.default_rng(seed) stream that "
                "Engine.save can persist")


# ------------------------------------------------------------------ FL005

def _strategy_class_names(mods: Sequence[_Module]) -> Set[str]:
    """Transitive closure of classes reaching ``Strategy`` (by name) or
    decorated with ``register_strategy`` across the analyzed files."""
    bases: Dict[str, Set[str]] = {}
    seeds: Set[str] = {"Strategy"}
    for mod in mods:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases[node.name] = {b for b in
                                ((_dotted(x) or "").split(".")[-1]
                                 for x in node.bases) if b}
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if (_dotted(target) or "").split(".")[-1] == \
                        "register_strategy":
                    seeds.add(node.name)
    out = set(seeds)
    changed = True
    while changed:
        changed = False
        for name, bs in bases.items():
            if name not in out and bs & out:
                out.add(name)
                changed = True
    return out


def _sig_problem(fn: ast.FunctionDef, required: Tuple[str, ...],
                 extras: Tuple[str, ...]) -> Optional[str]:
    args = fn.args
    names = [a.arg for a in args.args]
    if not names or names[0] not in ("self", "cls"):
        return "missing self"
    names = names[1:]
    if tuple(names[:len(required)]) != required:
        return f"positional args {tuple(names[:len(required)])!r}"
    tail = names[len(required):]
    n_defaults = len(args.defaults)
    defaulted = set(names[len(names) - n_defaults:]) if n_defaults else set()
    defaulted |= {a.arg for a, d in
                  zip(args.kwonlyargs, args.kw_defaults) if d is not None}
    has_varkw = args.kwarg is not None
    for t in tail:
        if t not in extras and not has_varkw:
            return f"unexpected parameter {t!r}"
        if t not in defaulted:
            return f"parameter {t!r} needs a default"
    for t in [a.arg for a in args.kwonlyargs]:
        if t not in extras and not has_varkw:
            return f"unexpected keyword-only parameter {t!r}"
    return None


def _check_fl005(mod: _Module, strategy_classes: Set[str], add) -> None:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef) or \
                node.name not in strategy_classes:
            continue
        for item in node.body:
            if not isinstance(item, ast.FunctionDef) or \
                    item.name not in _PROTOCOL_HOOKS:
                continue
            required, extras = _PROTOCOL_HOOKS[item.name]
            problem = _sig_problem(item, required, extras)
            if problem:
                opt = "".join(f", {e}=..." for e in extras)
                add("FL005", item,
                    f"{node.name}.{item.name} does not match the Strategy "
                    f"protocol ({problem}) — the engine dispatches on this "
                    "exact signature" + (
                        " (the comm_cost ids= probe)"
                        if item.name == "comm_cost" else ""),
                    f"def {item.name}(self, {', '.join(required)}{opt})")


# -------------------------------------------------------------------- driver

def _lint_module(mod: _Module, strategy_classes: Set[str],
                 select: Optional[Set[str]]) -> List[Finding]:
    findings: List[Finding] = []

    def add(code: str, node: ast.AST, message: str, fixit: str):
        if select and code not in select:
            return
        line = getattr(node, "lineno", 1)
        if not mod.lines.allows(code, line):
            return
        findings.append(Finding(code, mod.rel, line,
                                getattr(node, "col_offset", 0) + 1,
                                message, fixit))

    _check_fl001(mod, add)
    _check_fl002(mod, add)
    _check_fl003(mod, add)
    _check_fl004(mod, add)
    _check_fl005(mod, strategy_classes, add)
    return findings


def _iter_py_files(paths: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def _rel(path: Path, roots: Sequence[Path]) -> str:
    for r in roots:
        try:
            return path.resolve().relative_to(Path(r).resolve()).as_posix()
        except ValueError:
            continue
    return str(path)


def lint_paths(paths: Sequence, select: Optional[Iterable[str]] = None
               ) -> List[Finding]:
    """Lint every .py file under ``paths``; returns sorted findings."""
    roots = [Path(p) for p in paths]
    mods: List[_Module] = []
    for f in _iter_py_files(roots):
        mods.append(_Module(f, f.read_text(), _rel(f, roots)))
    sel = set(select) if select else None
    strategy_classes = _strategy_class_names(mods)
    findings: List[Finding] = []
    for mod in mods:
        findings.extend(_lint_module(mod, strategy_classes, sel))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def lint_source(source: str, path: str = "<string>",
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Single-module convenience entry point (tests, tooling)."""
    mod = _Module(Path(path), source, path)
    return sorted(_lint_module(mod, _strategy_class_names([mod]),
                               set(select) if select else None),
                  key=lambda f: (f.line, f.code))


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        prog="fleetlint",
        description="kernel-contract static analysis for the fleet engine")
    parser.add_argument("paths", nargs="*",
                        help="files or directories (default: the installed "
                             "repro package)")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule codes (e.g. FL001,FL003)")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)
    if args.list_rules:
        for code, title in sorted(RULES.items()):
            print(f"{code}  {title}")
        return 0
    paths = args.paths or [Path(__file__).resolve().parents[1]]
    select = args.select.split(",") if args.select else None
    findings = lint_paths(paths, select=select)
    for f in findings:
        print(f.format())
    n_files = len(_iter_py_files([Path(p) for p in paths]))
    if findings:
        print(f"fleetlint: {len(findings)} finding(s) in {n_files} files")
        return 1
    print(f"fleetlint: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Data pipeline: deterministic synthetic datasets + non-IID partitioning.

The container is offline, so CIFAR-10/100 are replaced by *learnable*
synthetic image datasets with identical shape/class structure: each class c
has a random but fixed prototype image; samples are prototype + noise. A
model must learn the class structure (accuracy is meaningful, chance =
1/n_classes), which is exactly what the paper's convergence-rate comparisons
need. Dirichlet(alpha) partitioning follows the paper (alpha = 0.5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class SyntheticImageDataset:
    images: np.ndarray   # [N, H, W, 3] float32
    labels: np.ndarray   # [N] int32
    n_classes: int

    def __len__(self):
        return len(self.labels)


def make_synthetic_images(n_samples: int, n_classes: int, image_size: int,
                          *, noise: float = 0.35, seed: int = 0,
                          proto_seed: int = None) -> SyntheticImageDataset:
    """``proto_seed`` fixes the class prototypes independently of the sample
    noise so train/test splits share one underlying distribution."""
    proto_rng = np.random.default_rng(seed if proto_seed is None else proto_seed)
    rng = np.random.default_rng(seed)
    protos = proto_rng.normal(0.0, 1.0, (n_classes, image_size, image_size, 3))
    labels = rng.integers(0, n_classes, n_samples)
    images = protos[labels] + rng.normal(0.0, noise,
                                         (n_samples, image_size, image_size, 3))
    return SyntheticImageDataset(images.astype(np.float32),
                                 labels.astype(np.int32), n_classes)


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        *, seed: int = 0, min_per_client: int = 2
                        ) -> List[np.ndarray]:
    """Paper §III-A: Dirichlet(alpha) class-skewed client shards.

    Returns a list of index arrays, one per client.
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    out = []
    all_idx = np.arange(len(labels))
    for s in shards:
        if len(s) < min_per_client:  # top up starved clients
            extra = rng.choice(all_idx, min_per_client - len(s))
            s = list(s) + extra.tolist()
        out.append(np.array(sorted(s), dtype=np.int64))
    return out


@dataclasses.dataclass
class ClientData:
    images: np.ndarray
    labels: np.ndarray

    def sample_batch(self, batch_size: int, rng: np.random.Generator):
        idx = rng.integers(0, len(self.labels), batch_size)
        return {"images": self.images[idx], "label": self.labels[idx]}


class DeviceData:
    """Device-resident view of a federated dataset: every client shard
    concatenated into ONE flat ``images``/``labels`` device array, plus the
    per-client offsets that translate shard-local sample indices to flat
    ones.

    This is what makes the round loop device-resident: instead of the host
    slicing/stacking image batches every local step, strategies draw *index*
    arrays (``sample_indices``) and the compiled kernel gathers the batch on
    device inside its ``lax.scan`` over local steps. Only O(steps x cohort x
    batch) int32s cross the host boundary per cohort; the pixels are
    uploaded once, at construction.

    Batch-RNG contract: index draws come from the SAME numpy stream, in the
    same (step-major, client-minor) order, as the legacy per-step
    ``ClientData.sample_batch`` host path — so a run through the
    device-resident path is batch-for-batch identical to the pre-refactor
    engine on the same seed.
    """

    def __init__(self, clients):
        import jax.numpy as jnp
        sizes = np.array([len(c.labels) for c in clients], np.int64)
        self.sizes = sizes
        self.offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        self.images = jnp.asarray(
            np.concatenate([c.images for c in clients], axis=0))
        self.labels = jnp.asarray(
            np.concatenate([c.labels for c in clients], axis=0))

    def sample_indices(self, ids, steps: int, batch_size: int,
                       rng: np.random.Generator) -> np.ndarray:
        """[steps, len(ids), batch_size] int32 flat-array indices, drawn in
        the legacy order (one ``integers`` call per (step, client))."""
        out = np.empty((steps, len(ids), batch_size), np.int32)
        for s in range(steps):
            for j, i in enumerate(ids):
                out[s, j] = self.offsets[i] + rng.integers(
                    0, self.sizes[i], batch_size)
        return out


def as_device_data(data: Dict[str, object]) -> DeviceData:
    """The (cached) device-resident view of a ``make_federated_data`` dict."""
    dd = data.get("_device")
    if dd is None:
        dd = data["_device"] = DeviceData(data["clients"])
    return dd


def make_federated_data(n_clients: int, *, n_classes: int = 10,
                        image_size: int = 16, samples: int = 4096,
                        alpha: float = 0.5, seed: int = 0,
                        noise: float = 0.35) -> Dict[str, object]:
    ds = make_synthetic_images(samples, n_classes, image_size, seed=seed,
                               noise=noise)
    shards = dirichlet_partition(ds.labels, n_clients, alpha, seed=seed + 1)
    clients = [ClientData(ds.images[s], ds.labels[s]) for s in shards]
    test = make_synthetic_images(max(512, samples // 8), n_classes,
                                 image_size, seed=seed + 2, proto_seed=seed,
                                 noise=noise)
    return {"clients": clients, "test": test, "dataset": ds}


def synthetic_lm_batches(vocab: int, seq_len: int, batch: int, steps: int,
                         *, seed: int = 0):
    """Markov-chain token stream (learnable LM data for the e2e driver)."""
    rng = np.random.default_rng(seed)
    # sparse transition structure so a model can reduce loss below ln(V)
    trans = rng.integers(0, vocab, (vocab, 4))
    for _ in range(steps):
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, vocab, batch)
        choices = rng.integers(0, 4, (batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = trans[toks[:, t], choices[:, t]]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}

"""Data pipeline: deterministic synthetic datasets + non-IID partitioning.

The container is offline, so CIFAR-10/100 are replaced by *learnable*
synthetic image datasets with identical shape/class structure: each class c
has a random but fixed prototype image; samples are prototype + noise. A
model must learn the class structure (accuracy is meaningful, chance =
1/n_classes), which is exactly what the paper's convergence-rate comparisons
need. Dirichlet(alpha) partitioning follows the paper (alpha = 0.5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class SyntheticImageDataset:
    images: np.ndarray   # [N, H, W, 3] float32
    labels: np.ndarray   # [N] int32
    n_classes: int

    def __len__(self):
        return len(self.labels)


def make_synthetic_images(n_samples: int, n_classes: int, image_size: int,
                          *, noise: float = 0.35, seed: int = 0,
                          proto_seed: int = None) -> SyntheticImageDataset:
    """``proto_seed`` fixes the class prototypes independently of the sample
    noise so train/test splits share one underlying distribution."""
    proto_rng = np.random.default_rng(seed if proto_seed is None else proto_seed)
    rng = np.random.default_rng(seed)
    protos = proto_rng.normal(0.0, 1.0, (n_classes, image_size, image_size, 3))
    labels = rng.integers(0, n_classes, n_samples)
    images = protos[labels] + rng.normal(0.0, noise,
                                         (n_samples, image_size, image_size, 3))
    return SyntheticImageDataset(images.astype(np.float32),
                                 labels.astype(np.int32), n_classes)


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        *, seed: int = 0, min_per_client: int = 2
                        ) -> List[np.ndarray]:
    """Paper §III-A: Dirichlet(alpha) class-skewed client shards.

    Returns a list of index arrays, one per client.
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            shards[i].extend(part.tolist())
    out = []
    all_idx = np.arange(len(labels))
    for s in shards:
        if len(s) < min_per_client:  # top up starved clients
            extra = rng.choice(all_idx, min_per_client - len(s))
            s = list(s) + extra.tolist()
        out.append(np.array(sorted(s), dtype=np.int64))
    return out


@dataclasses.dataclass
class ClientData:
    images: np.ndarray
    labels: np.ndarray

    def sample_batch(self, batch_size: int, rng: np.random.Generator):
        idx = rng.integers(0, len(self.labels), batch_size)
        return {"images": self.images[idx], "label": self.labels[idx]}


def make_federated_data(n_clients: int, *, n_classes: int = 10,
                        image_size: int = 16, samples: int = 4096,
                        alpha: float = 0.5, seed: int = 0,
                        noise: float = 0.35) -> Dict[str, object]:
    ds = make_synthetic_images(samples, n_classes, image_size, seed=seed,
                               noise=noise)
    shards = dirichlet_partition(ds.labels, n_clients, alpha, seed=seed + 1)
    clients = [ClientData(ds.images[s], ds.labels[s]) for s in shards]
    test = make_synthetic_images(max(512, samples // 8), n_classes,
                                 image_size, seed=seed + 2, proto_seed=seed,
                                 noise=noise)
    return {"clients": clients, "test": test, "dataset": ds}


def synthetic_lm_batches(vocab: int, seq_len: int, batch: int, steps: int,
                         *, seed: int = 0):
    """Markov-chain token stream (learnable LM data for the e2e driver)."""
    rng = np.random.default_rng(seed)
    # sparse transition structure so a model can reduce loss below ln(V)
    trans = rng.integers(0, vocab, (vocab, 4))
    for _ in range(steps):
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, vocab, batch)
        choices = rng.integers(0, 4, (batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = trans[toks[:, t], choices[:, t]]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}

from repro.data.synthetic import (SyntheticImageDataset, dirichlet_partition,
                                  make_federated_data, synthetic_lm_batches)  # noqa: F401

"""The federated engine: ONE round loop for every strategy.

``Engine.run_round`` owns everything method-independent — availability
draws, per-round client sampling (``sample_frac``), batch RNG ordering,
cohorting, the metrics ``Accountant``, history and eval — and delegates the
method-specific phases (cohort update, server fold, aggregation, per-client
communication cost) to a ``Strategy`` resolved from the registry. Adding a
scenario means registering a strategy, not copy-pasting a trainer.

Construction is either direct::

    Engine(cfg, n_clients=16, strategy="ssfl", lr=0.25)

or builder-style::

    engine = (Engine.builder(cfg)
              .clients(16, availability=0.9)
              .strategy("ssfl")
              .optimizer("sgd", lr=0.25)
              .data(alpha=0.5, noise=0.7)
              .build())
"""
from __future__ import annotations

import functools
from typing import Dict, List, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fault import AvailabilityModel
from repro.federated import metrics as MET
from repro.federated.simulator import make_fleet
from repro.federated.state import TrainState, init_train_state
from repro.federated.strategies import RoundContext, Strategy, get_strategy
from repro.models import model as M
from repro.optim import Optimizer, get_optimizer


class Engine:
    def __init__(self, cfg: ModelConfig, n_clients: int,
                 strategy: Union[str, Strategy] = "ssfl", *,
                 seed: int = 0, lr: float = None, local_steps: int = 2,
                 batch_size: int = 16, availability: float = 1.0,
                 sample_frac: float = 1.0,
                 optimizer: Union[str, Optimizer] = "sgd",
                 data=None, device_model: MET.DeviceModel = None,
                 alpha: float = 0.5, noise: float = 0.35):
        assert 0.0 < sample_frac <= 1.0
        self.cfg = cfg
        self.strategy = (get_strategy(strategy)
                         if isinstance(strategy, str) else strategy)
        # lr is baked into name-resolved optimizers (default 0.05); a
        # pre-built Optimizer instance has its rate inside its closures, so
        # engine.lr stays None there unless the caller states it — it never
        # silently disagrees with the update rule
        if isinstance(optimizer, str):
            lr = 0.05 if lr is None else lr
            self.optimizer = get_optimizer(optimizer, lr)
        else:
            self.optimizer = optimizer
        self.lr, self.local_steps = lr, local_steps
        self.batch_size, self.sample_frac = batch_size, sample_frac
        fleet = make_fleet(cfg, n_clients, seed=seed,
                           fixed_depth=self.strategy.fixed_depth(cfg))
        self.strategy.prepare_fleet(cfg, fleet)
        self.avail_model = AvailabilityModel(availability, seed=seed + 7)
        # sampling stream is separate from the batch stream so that
        # sample_frac=1.0 runs are bit-identical to never drawing at all
        self._sample_rng = np.random.default_rng(seed + 13)
        from repro.data.synthetic import make_federated_data
        self.data = data or make_federated_data(
            n_clients, n_classes=cfg.n_classes or 10,
            image_size=cfg.image_size, alpha=alpha, seed=seed, noise=noise)
        self.state: TrainState = init_train_state(cfg, n_clients, seed=seed,
                                                  fleet=fleet)
        self.accountant = MET.Accountant(device_model)
        self.history: List[Dict] = []

    @classmethod
    def builder(cls, cfg: ModelConfig) -> "EngineBuilder":
        return EngineBuilder(cfg)

    # ------------------------------------------------------------- one round
    def run_round(self) -> Dict:
        state, strat = self.state, self.strategy
        avail = self.avail_model.draw(state.fleet.n_clients)
        ctx = RoundContext(avail=avail,
                           participants=self._draw_participants(),
                           batch_fn=self._stack_batches)
        ws = strat.init_round(self, ctx)
        stats = MET.RoundStats()
        server_busy_s = 0.0
        for d, ids in strat.cohorts(self, ctx).items():
            res = strat.cohort_step(self, ctx, ws, d, ids)
            strat.fold_server(self, ws, d, ids, res)
            server_busy_s += self._account_cohort(stats, ctx, d, ids, res)
        stats.round_time_s += server_busy_s
        stats.energy_j += self.accountant.dm.server_power_w * server_busy_s
        state.params, loss = strat.aggregate(self, ws)
        state.round_idx += 1
        self.accountant.log_round(stats)
        rec = {"round": state.round_idx, "loss": loss,
               **self.accountant.summary()}
        self.history.append(rec)
        return rec

    def _draw_participants(self) -> np.ndarray:
        n = self.state.fleet.n_clients
        if self.sample_frac >= 1.0:
            return np.ones(n, bool)
        k = max(1, int(round(self.sample_frac * n)))
        mask = np.zeros(n, bool)
        mask[self._sample_rng.choice(n, size=k, replace=False)] = True
        return mask

    def _stack_batches(self, ids):
        batches = [self.data["clients"][i].sample_batch(
            self.batch_size, self.state.rng) for i in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    def _account_cohort(self, stats: MET.RoundStats, ctx: RoundContext,
                        d: int, ids, res) -> float:
        """Method-independent cost model over one cohort; returns the
        server busy-time contribution (0 for serverless strategies)."""
        dm = self.accountant.dm
        n_tok = self.tokens_per_batch()
        cflops = MET.dense_train_flops(res.client_params, n_tok) \
            * self.local_steps
        # comm_cost depends only on (d, available): two variants per cohort
        cost = {av: self.strategy.comm_cost(self, d, av)
                for av in (True, False)}
        for i in ids:
            prof = self.state.fleet.profiles[i]
            nbytes, nmsg = cost[bool(ctx.avail[i])]
            t = cflops / dm.client_speed(prof.mem_gb) + dm.comm_time_s(
                nbytes, prof.lat_ms, nmsg)
            stats.comm_bytes += nbytes
            stats.client_flops += cflops
            stats.round_time_s = max(stats.round_time_s, t)
            stats.energy_j += dm.client_power_w * t
            stats.n_messages += nmsg
        sflops = MET.dense_train_flops(res.server_params, n_tok) \
            * self.local_steps * len(ids)
        stats.server_flops += sflops
        return sflops / (dm.server_gflops * 1e9)

    # -------------------------------------------------------------- utilities
    def tokens_per_batch(self) -> int:
        cfg = self.cfg
        if cfg.family == "vit":
            return self.batch_size * (cfg.image_size // cfg.patch_size) ** 2
        return self.batch_size * 128

    def smashed_bytes(self, d: int) -> int:
        return self.tokens_per_batch() * self.cfg.d_model * 4  # fp32 acts

    def evaluate(self, max_batches: int = 8) -> float:
        cfg = self.cfg
        test = self.data["test"]
        bs = 64
        correct = total = 0
        for i in range(0, min(len(test.labels), max_batches * bs), bs):
            batch = {"images": jnp.asarray(test.images[i:i + bs]),
                     "label": jnp.asarray(test.labels[i:i + bs])}
            logits = predict(cfg, self.state.params, batch)
            pred = np.asarray(jnp.argmax(logits, -1))
            correct += int((pred == test.labels[i:i + bs]).sum())
            total += len(pred)
        return correct / max(total, 1)

    def train(self, n_rounds: int, *, eval_every: int = 5,
              target_accuracy: float = None, verbose: bool = False):
        for r in range(n_rounds):
            rec = self.run_round()
            if (r + 1) % eval_every == 0 or r == n_rounds - 1:
                rec["accuracy"] = self.evaluate()
                if verbose:
                    print(f"[{self.strategy.name}] round {rec['round']} "
                          f"loss={rec['loss']:.3f} acc={rec['accuracy']:.3f}")
                if target_accuracy and rec["accuracy"] >= target_accuracy:
                    return rec
        return self.history[-1]


class EngineBuilder:
    """Fluent construction for the common quickstart path."""

    def __init__(self, cfg: ModelConfig):
        self._cfg = cfg
        self._kw: Dict = {"n_clients": 8}

    def clients(self, n: int, *, availability: float = 1.0,
                sample_frac: float = 1.0) -> "EngineBuilder":
        self._kw.update(n_clients=n, availability=availability,
                        sample_frac=sample_frac)
        return self

    def strategy(self, name: Union[str, Strategy]) -> "EngineBuilder":
        self._kw["strategy"] = name
        return self

    def optimizer(self, name: Union[str, Optimizer], *, lr: float = None,
                  **opt_kw) -> "EngineBuilder":
        if isinstance(name, str):
            lr = 0.05 if lr is None else lr
            self._kw.update(optimizer=get_optimizer(name, lr, **opt_kw),
                            lr=lr)
        else:
            # a pre-built Optimizer already has its rate baked in; only
            # record lr when the caller states it, so engine.lr never
            # silently disagrees with the update rule
            self._kw["optimizer"] = name
            if lr is not None:
                self._kw["lr"] = lr
        return self

    def data(self, *, alpha: float = 0.5, noise: float = 0.35,
             dataset=None) -> "EngineBuilder":
        self._kw.update(alpha=alpha, noise=noise, data=dataset)
        return self

    def rounds(self, *, local_steps: int = 2, batch_size: int = 16,
               seed: int = 0) -> "EngineBuilder":
        self._kw.update(local_steps=local_steps, batch_size=batch_size,
                        seed=seed)
        return self

    def device_model(self, dm: MET.DeviceModel) -> "EngineBuilder":
        self._kw["device_model"] = dm
        return self

    def build(self) -> Engine:
        kw = dict(self._kw)   # builder stays reusable (seed sweeps etc.)
        return Engine(self._cfg, kw.pop("n_clients"), **kw)


@functools.partial(jax.jit, static_argnames=("cfg",))
def predict(cfg: ModelConfig, params, batch):
    Lfull = cfg.split_stack_len
    z, _ = M.prefix_apply(cfg, params, batch, Lfull)
    logits, _ = M.suffix_apply(cfg, params, z, batch, Lfull)
    return logits

"""The federated engine: ONE round loop for every strategy.

``Engine.run_round`` owns everything method-independent — arrival /
availability draws, per-round client sampling (``sample_frac``), staleness
tracking, batch RNG ordering, cohorting, the metrics ``Accountant``,
history and eval — and delegates the method-specific phases (cohort update,
server fold, aggregation, per-client communication cost) to a ``Strategy``
resolved from the registry. Adding a scenario means registering a strategy,
not copy-pasting a trainer.

Device residency / bounded compile
----------------------------------
One round is a small, fixed set of compiled programs regardless of fleet
composition: cohorts run in padded size buckets (``federated.bucketing``),
all local steps of a cohort execute as one scanned kernel that gathers its
batches on device from the flat dataset (``engine.device_data``), and the
round's training outputs accumulate in full-fleet stacked device buffers
(``strategies.base.fleet_workspace``) that aggregation consumes directly
with a validity mask. Host floats materialize once per round — the
trained-mask/loss sync in ``Strategy._finish_aggregation`` — plus the pure
cost-model arithmetic in ``_account_cohort``, which never touches device
data.

Multi-device fleet execution
----------------------------
Pass ``mesh=`` (e.g. ``repro.launch.mesh.make_fleet_mesh()``) and the
client axis stops being storage-only sharding: stacked state and workspace
buffers place with ``launch.sharding.fleet_pspecs``, bucket sizes round up
to a multiple of the mesh's data extent (every shard owns whole slots —
padding is a numerical no-op by the padded-slot contract), and each cohort
kernel dispatches to its ``shard_map`` variant
(``bucketing.FleetKernel.sharded``), whose cross-slot reductions ``psum``
over the fleet axis. A 1-device mesh (or a bucket the mesh cannot split
evenly, e.g. an explicit ladder entry) falls back to the replicated
kernel — same numbers, no shard_map.

Construction is either direct::

    Engine(cfg, n_clients=16, strategy="ssfl", lr=0.25)

or builder-style::

    engine = (Engine.builder(cfg)
              .clients(16, availability=0.9)
              .strategy("ssfl")
              .optimizer("sgd", lr=0.25)
              .data(alpha=0.5, noise=0.7)
              .build())

RNG-stream contract
-------------------
Every source of randomness is a separate stream with a fixed offset from
the construction ``seed``, so adding a knob never perturbs the others:

  seed          — global params (jax PRNG), fleet profiles, the synthetic
                  data, and the batch-sampling stream (``TrainState.rng``,
                  drawn in cohort order by ``batch_fn``)
  seed + 1      — per-client local heads phi_i (one jax sub-key each)
  seed + 7      — server availability (``avail_model``, an
                  :class:`~repro.core.fault.ArrivalProcess`)
  seed + 13     — per-round client sampling (``sample_frac``); a
                  ``sample_frac=1.0`` run never touches this stream, so it
                  is bit-identical to a run without the knob
  seed + 21     — client participation (the strategy-supplied or
                  explicitly passed ``participation`` arrival process)

``Engine.save`` persists the position of every stream (plus the Markov
on/off state) in the checkpoint manifest; ``Engine.restore`` rewinds them,
so a resumed run is bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import functools
import inspect
from typing import Dict, List, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fault import ArrivalProcess, AvailabilityModel
from repro.federated import metrics as MET
from repro.federated.simulator import make_fleet
from repro.federated.state import TrainState, init_train_state
from repro.federated.strategies import RoundContext, Strategy, get_strategy
from repro.models import model as M
from repro.optim import Optimizer, get_optimizer


class Engine:
    def __init__(self, cfg: ModelConfig, n_clients: int,
                 strategy: Union[str, Strategy] = "ssfl", *,
                 seed: int = 0, lr: float = None, local_steps: int = 2,
                 batch_size: int = 16,
                 availability: Union[float, ArrivalProcess] = 1.0,
                 participation: ArrivalProcess = None,
                 sample_frac: float = 1.0,
                 optimizer: Union[str, Optimizer] = "sgd",
                 data=None, device_model: MET.DeviceModel = None,
                 alpha: float = 0.5, noise: float = 0.35,
                 bucketing="ladder", mesh=None, sanitize: bool = False,
                 width_tiers=None, cross_tier: str = "fused"):
        assert 0.0 < sample_frac <= 1.0
        self.cfg = cfg
        # cross-tier TPGF: with >1 width tier in a cohort, "fused" (the
        # paper path) runs every tier from the same server snapshot and
        # fuses the per-tier updates into ONE with tpgf.fuse_tiers;
        # "chained" keeps the pre-fusion sequential chaining (each tier
        # continues from the previous tier's server branch) as the
        # per-tier comparator the benchmarks sweep against. Homogeneous
        # fleets never branch — one width group is the legacy call.
        if cross_tier not in ("fused", "chained"):
            raise ValueError(
                f"cross_tier={cross_tier!r}: expected 'fused' or 'chained'")
        self.cross_tier = cross_tier
        # sanitize=True swaps every bucket kernel for its checkify-
        # instrumented variant (NaN/inf + OOB-gather checks, per-slot
        # attribution via SlotSanitizerError). Debug mode: it adds a host
        # sync per kernel call, so the one-host-sync contract — and the
        # round-path goldens — only hold with the default False.
        self.sanitize = bool(sanitize)
        self.strategy = (get_strategy(strategy)
                         if isinstance(strategy, str) else strategy)
        # cohort-size bucket ladder: "ladder" (default powers of two),
        # "exact" (no padding — one compile per distinct cohort size; the
        # benchmark's pre-refactor reference mode), or an explicit sequence
        if bucketing == "ladder":
            self.bucket_ladder = None
        elif bucketing == "exact":
            self.bucket_ladder = ()
        elif isinstance(bucketing, (tuple, list)) and all(
                isinstance(b, int) and b > 0 for b in bucketing):
            self.bucket_ladder = tuple(bucketing)
        else:
            raise ValueError(
                f"bucketing={bucketing!r}: expected 'ladder', 'exact', or "
                "a sequence of positive ints (an explicit bucket ladder)")
        self.mesh = mesh
        # lr is baked into name-resolved optimizers (default 0.05); a
        # pre-built Optimizer instance has its rate inside its closures, so
        # engine.lr stays None there unless the caller states it — it never
        # silently disagrees with the update rule
        if isinstance(optimizer, str):
            lr = 0.05 if lr is None else lr
            self.optimizer = get_optimizer(optimizer, lr)
        else:
            self.optimizer = optimizer
        self.lr, self.local_steps = lr, local_steps
        self.batch_size, self.sample_frac = batch_size, sample_frac
        self.accountant = MET.Accountant(device_model)
        fleet = make_fleet(cfg, n_clients, seed=seed,
                           fixed_depth=self.strategy.fixed_depth(cfg))
        if width_tiers is not None:
            # supernet width ladder: snap each client's memory budget to a
            # tier (core.allocation.allocate_widths); strategies group
            # same-width sub-cohorts and kernels key on (width, bucket) —
            # depth rides as a runtime array. Default None keeps
            # fleet.widths all-ones — the bit-exact legacy path.
            from repro.core import allocation as AL
            fleet.widths = AL.allocate_widths(
                [p.mem_gb for p in fleet.profiles], width_tiers)
        self.width_tiers = None if width_tiers is None \
            else tuple(sorted(float(t) for t in width_tiers))
        self._call_prepare_fleet(cfg, fleet)
        self.avail_model: ArrivalProcess = (
            availability if isinstance(availability, ArrivalProcess)
            else AvailabilityModel(availability, seed=seed + 7))
        # sampling stream is separate from the batch stream so that
        # sample_frac=1.0 runs are bit-identical to never drawing at all
        self._sample_rng = np.random.default_rng(seed + 13)
        self.participation: ArrivalProcess = (
            participation
            or self.strategy.participation_process(cfg, n_clients,
                                                   seed + 21))
        from repro.data.synthetic import make_federated_data
        self.data = data or make_federated_data(
            n_clients, n_classes=cfg.n_classes or 10,
            image_size=cfg.image_size, alpha=alpha, seed=seed, noise=noise)
        self.state: TrainState = init_train_state(cfg, n_clients, seed=seed,
                                                  fleet=fleet)
        if mesh is not None:
            from repro.launch import sharding as SH
            self.state.local_heads = SH.shard_fleet(self.state.local_heads,
                                                    mesh)
        self._staleness = np.zeros(n_clients, np.int64)
        self._server_updates = 0    # rounds in which any client had a server
        self.history: List[Dict] = []

    def _call_prepare_fleet(self, cfg, fleet):
        """Pass ``device_model`` only to hooks that accept it, so strategies
        written against the original ``prepare_fleet(cfg, fleet)`` protocol
        keep working unchanged."""
        sig = inspect.signature(self.strategy.prepare_fleet)
        params = sig.parameters.values()
        if "device_model" in sig.parameters or any(
                p.kind == p.VAR_KEYWORD for p in params):
            self.strategy.prepare_fleet(cfg, fleet,
                                        device_model=self.accountant.dm)
        else:
            self.strategy.prepare_fleet(cfg, fleet)

    @classmethod
    def builder(cls, cfg: ModelConfig) -> "EngineBuilder":
        return EngineBuilder(cfg)

    # ----------------------------------------------------- device residency
    @property
    def device_data(self):
        """The flat device-resident dataset view (built on first use).
        With a fleet mesh the pixels replicate across its devices ONCE
        here — otherwise every sharded kernel call would re-broadcast the
        dataset at the shard_map boundary."""
        from repro.data.synthetic import as_device_data
        dd = as_device_data(self.data)
        if self.mesh is not None and \
                getattr(dd, "_fleet_mesh", None) is not self.mesh:
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(self.mesh, PartitionSpec())
            dd.images = jax.device_put(dd.images, rep)
            dd.labels = jax.device_put(dd.labels, rep)
            dd._fleet_mesh = self.mesh
        return dd

    def bucket_for(self, n: int) -> int:
        """Cohort-size bucket under this engine's ladder, rounded up to a
        multiple of the fleet-mesh data extent so every shard owns whole
        slots (``fleet_shards`` is 1 without a mesh — no change)."""
        from repro.federated.bucketing import bucket_size
        return bucket_size(n, self.bucket_ladder,
                           multiple_of=self.fleet_shards)

    @property
    def fleet_shards(self) -> int:
        """Number of shards the bucket-slot/client axis splits into: the
        product of the mesh's data-axis sizes (1 without a mesh)."""
        if self.mesh is None:
            return 1
        from repro.launch.sharding import fleet_extent
        return fleet_extent(self.mesh)

    def kernel_fn(self, kernel, bucket: int):
        """The callable to run one bucketed cohort with: the kernel's
        per-mesh ``shard_map`` variant when a multi-device fleet mesh is
        configured and the bucket splits into whole slots per shard, else
        the replicated jit (identical semantics, one device).

        With ``sanitize=True`` the checkify-instrumented variant runs
        instead (always replicated — see ``FleetKernel.sanitized``): each
        call unpacks ``(err, out)`` and raises ``SlotSanitizerError`` with
        the offending bucket slots if any float/index check tripped."""
        from repro.federated.bucketing import FleetKernel, sanitize_failure
        if self.sanitize and isinstance(kernel, FleetKernel):
            fn = kernel.sanitized()
            name = getattr(kernel, "__name__", "kernel")

            def run(*args):
                err, out = fn(*args)
                sanitize_failure(err, out, bucket, kernel=name)
                return out

            return run
        shards = self.fleet_shards
        if (shards > 1 and isinstance(kernel, FleetKernel)
                and bucket % shards == 0):
            return kernel.sharded(self.mesh)
        return kernel

    # ------------------------------------------------------------- one round
    def run_round(self) -> Dict:
        state, strat = self.state, self.strategy
        avail = self.avail_model.draw(state.fleet.n_clients)
        ctx = RoundContext(avail=avail,
                           participants=self._draw_participants(),
                           batch_fn=self._stack_batches,
                           sample_indices=self._sample_indices,
                           staleness=self._staleness.copy())
        ws = strat.init_round(self, ctx)
        stats = MET.RoundStats()
        server_busy_s = 0.0
        head_trained = False
        for d, ids in strat.cohorts(self, ctx).items():
            res = strat.cohort_step(self, ctx, ws, d, ids)
            strat.fold_server(self, ws, d, ids, res)
            server_busy_s += self._account_cohort(stats, ctx, d, ids, res)
            # the global head learns when a cohort reaches the server — or
            # trains the full model locally (serverless strategies)
            if res.server_params == 0 or bool(ctx.avail[ids].any()):
                head_trained = True
        stats.round_time_s += server_busy_s
        stats.energy_j += self.accountant.dm.server_power_w * server_busy_s
        state.params, loss = strat.aggregate(self, ws)
        trained = ctx.participants & state.fleet.feasible
        self._staleness = np.where(trained, 0, self._staleness + 1)
        if head_trained:
            self._server_updates += 1
        state.round_idx += 1
        self.accountant.log_round(stats)
        rec = {"round": state.round_idx, "loss": loss,
               **self.accountant.summary()}
        self.history.append(rec)
        return rec

    def _draw_participants(self) -> np.ndarray:
        """sample_frac subset ∩ the participation arrival process (when one
        is configured); all-True when neither knob is active."""
        n = self.state.fleet.n_clients
        if self.sample_frac >= 1.0:
            mask = np.ones(n, bool)
        else:
            k = max(1, int(round(self.sample_frac * n)))
            mask = np.zeros(n, bool)
            mask[self._sample_rng.choice(n, size=k, replace=False)] = True
        if self.participation is not None:
            mask &= self.participation.draw(n)
        return mask

    def _stack_batches(self, ids, batch_size: int = None):
        """Legacy host path: ids -> stacked batch; co-tuning strategies pass
        their per-cohort ``batch_size``, everyone else gets the engine
        default. Batches are drawn from ``state.rng`` in call order (the
        batch-stream contract). The built-in strategies use
        :meth:`_sample_indices` + on-device gather instead; this hook stays
        for strategies written against the PR-1 protocol."""
        bs = self.batch_size if batch_size is None else batch_size
        batches = [self.data["clients"][i].sample_batch(bs, self.state.rng)
                   for i in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    def _sample_indices(self, ids, steps: int, batch_size: int = None):
        """Device-resident path: [steps, len(ids), B] flat-dataset indices,
        drawn from ``state.rng`` in the same order ``_stack_batches`` would
        have (the batch-stream contract — both paths consume identical
        draws, so they are interchangeable per cohort, never mixed within
        one)."""
        bs = self.batch_size if batch_size is None else batch_size
        return self.device_data.sample_indices(ids, steps, bs, self.state.rng)

    def _account_cohort(self, stats: MET.RoundStats, ctx: RoundContext,
                        d: int, ids, res) -> float:
        """Method-independent cost model over one cohort; returns the
        server busy-time contribution (0 for serverless strategies). Pure
        host arithmetic over profile scalars — device arrays are never
        synced here."""
        dm = self.accountant.dm
        # co-tuning strategies report their cohort's effective batch tokens
        n_tok = res.tokens_per_batch or self.tokens_per_batch()
        cflops = MET.dense_train_flops(res.client_params, n_tok) \
            * self.local_steps
        per_id = self._comm_cost_takes_ids()
        if per_id:
            # ids-aware hook: exact per-client arrays (HASFL prices each
            # client at its own tuned batch size)
            cost = {av: self.strategy.comm_cost(self, d, av, ids=ids)
                    for av in (True, False)}
        else:
            # legacy hook: comm_cost depends only on (d, available)
            cost = {av: self.strategy.comm_cost(self, d, av)
                    for av in (True, False)}
        def pick(v, j):
            a = np.asarray(v).reshape(-1)   # per-id array or a shared scalar
            return int(a[j]) if a.size > 1 else int(a[0])

        for j, i in enumerate(ids):
            prof = self.state.fleet.profiles[i]
            nbytes, nmsg = cost[bool(ctx.avail[i])]
            if per_id:
                nbytes, nmsg = pick(nbytes, j), pick(nmsg, j)
            t = cflops / dm.client_speed(prof.mem_gb) + dm.comm_time_s(
                nbytes, prof.lat_ms, nmsg)
            stats.comm_bytes += nbytes
            stats.client_flops += cflops
            stats.round_time_s = max(stats.round_time_s, t)
            stats.energy_j += dm.client_power_w * t
            stats.n_messages += nmsg
        sflops = MET.dense_train_flops(res.server_params, n_tok) \
            * self.local_steps * len(ids)
        stats.server_flops += sflops
        return sflops / (dm.server_gflops * 1e9)

    def _comm_cost_takes_ids(self) -> bool:
        """Back-compat signature probe, cached per strategy instance: the
        extended hook is ``comm_cost(engine, d, available, ids=None)`` and
        returns per-id arrays when ids are passed; strategies written
        against the PR-1 three-argument protocol keep working unchanged."""
        cached = getattr(self, "_comm_ids_ok", None)
        if cached is not None:
            return cached
        sig = inspect.signature(self.strategy.comm_cost)
        self._comm_ids_ok = "ids" in sig.parameters or any(
            p.kind == p.VAR_KEYWORD for p in sig.parameters.values())
        return self._comm_ids_ok

    # -------------------------------------------------------------- utilities
    def tokens_per_batch(self) -> int:
        return self.batch_size * self.tokens_per_sample()

    def tokens_per_sample(self) -> int:
        cfg = self.cfg
        if cfg.family == "vit":
            return (cfg.image_size // cfg.patch_size) ** 2
        return 128

    def smashed_bytes(self, d: int) -> int:
        # activations cross the wire in the model's compute dtype
        itemsize = jnp.dtype(self.cfg.dtype).itemsize
        return self.tokens_per_batch() * self.cfg.d_model * itemsize

    def evaluate(self, max_batches: int = 8, *, head: str = "auto") -> float:
        """Test accuracy of the current global model.

        head="global" — the server-side classifier (paper's main metric).
        head="local"  — fault-tolerant client-side ensemble: each client
                        runs its depth-d_i prefix + its phi_i head, logits
                        are averaged (paper §II-C inference; what a fleet
                        that never reached the server can actually serve).
        head="auto"   — "global" once any round has trained the global
                        head (a cohort reached the server, or a serverless
                        strategy trained the full model locally), else
                        "local" (the Table III 0%-availability row).
        """
        if head not in ("auto", "global", "local"):
            raise ValueError(head)
        if head == "auto":
            head = "global" if self._server_updates > 0 else "local"
        cfg = self.cfg
        test = self.data["test"]
        bs = 64
        correct = total = 0
        for i in range(0, min(len(test.labels), max_batches * bs), bs):
            batch = {"images": jnp.asarray(test.images[i:i + bs]),
                     "label": jnp.asarray(test.labels[i:i + bs])}
            if head == "global":
                logits = predict(cfg, self.state.params, batch)
            else:
                logits = self._local_ensemble_logits(batch)
            pred = np.asarray(jnp.argmax(logits, -1))
            correct += int((pred == test.labels[i:i + bs]).sum())
            total += len(pred)
        return correct / max(total, 1)

    def _local_ensemble_logits(self, batch):
        """Mean of per-client fault-tolerant head logits, each computed at
        the client's own split depth with its own phi_i. Degrades to the
        global head when no client is feasible (nobody ever trained)."""
        fleet = self.state.fleet
        acc = None
        n = 0
        for i in range(fleet.n_clients):
            if not fleet.feasible[i]:
                continue
            params = {**self.state.params, **self.state.head_for(i)}
            logits = local_predict(self.cfg, params, batch,
                                   int(fleet.depths[i]))
            acc = logits if acc is None else acc + logits
            n += 1
        if acc is None:
            return predict(self.cfg, self.state.params, batch)
        return acc / n

    def train(self, n_rounds: int, *, eval_every: int = 5,
              target_accuracy: float = None, verbose: bool = False):
        for r in range(n_rounds):
            rec = self.run_round()
            if (r + 1) % eval_every == 0 or r == n_rounds - 1:
                rec["accuracy"] = self.evaluate()
                if verbose:
                    print(f"[{self.strategy.name}] round {rec['round']} "
                          f"loss={rec['loss']:.3f} acc={rec['accuracy']:.3f}")
                if target_accuracy and rec["accuracy"] >= target_accuracy:
                    return rec
        return self.history[-1]

    # ------------------------------------------------------------ checkpoint
    def save(self, path: str, *, meta: Dict = None):
        """``TrainState.save`` plus the engine's own stream positions
        (availability / sampling / participation RNGs, staleness counters),
        so :meth:`restore` resumes bit-identically. Strategy-owned
        cross-round state — kernel server moments, FedOpt server moments,
        the buffered-async update buffer — rides along automatically
        because it lives in ``TrainState.opt_state`` slots. The metrics
        ledger and history are NOT persisted — a restored engine accounts
        from zero."""
        meta = dict(meta or {})
        streams = {"avail": self.avail_model.get_state(),
                   "sample": self._sample_rng.bit_generator.state,
                   "staleness": self._staleness.tolist(),
                   "server_updates": self._server_updates,
                   # width tiers ride the stream manifest because fleet
                   # profiles are reconstructed from the seed, not
                   # persisted — a strategy (hasfl retune) may have moved
                   # them since construction
                   "widths": np.asarray(self.state.fleet.widths,
                                        np.float64).tolist()}
        if self.participation is not None:
            streams["participation"] = self.participation.get_state()
        meta["engine_streams"] = streams
        self.state.save(path, meta=meta)

    def restore(self, path: str) -> "Engine":
        """Inverse of :meth:`save`; the engine must have been constructed
        with the same (cfg, n_clients, strategy, optimizer) shape."""
        self.state.restore(path)
        if self.mesh is not None:
            # TrainState.restore rebuilds arrays on the default device;
            # re-apply the client-axis placement the constructor set up
            from repro.launch import sharding as SH
            self.state.local_heads = SH.shard_fleet(self.state.local_heads,
                                                    self.mesh)
        # adopted opt_state must be re-validated by its owners: the kernel
        # server moments and the fedavg-family FedOpt fold (both cache in
        # _server_opt_ok), async_buffered's flush moments (_fedopt_ok),
        # and its update buffer (_buffer_ok)
        self._server_opt_ok = None
        self._fedopt_ok = None
        self._buffer_ok = None
        streams = self.state.last_restore_meta.get("engine_streams")
        if streams:
            self.avail_model.set_state(streams["avail"])
            self._sample_rng.bit_generator.state = streams["sample"]
            self._staleness = np.asarray(streams["staleness"], np.int64)
            self._server_updates = int(streams.get("server_updates", 0))
            if "widths" in streams:
                self.state.fleet.widths = np.asarray(streams["widths"],
                                                     np.float64)
            if self.participation is not None \
                    and "participation" in streams:
                self.participation.set_state(streams["participation"])
        return self


class EngineBuilder:
    """Fluent construction for the common quickstart path."""

    def __init__(self, cfg: ModelConfig):
        self._cfg = cfg
        self._kw: Dict = {"n_clients": 8}

    def clients(self, n: int, *,
                availability: Union[float, ArrivalProcess] = 1.0,
                sample_frac: float = 1.0,
                participation: ArrivalProcess = None) -> "EngineBuilder":
        self._kw.update(n_clients=n, availability=availability,
                        sample_frac=sample_frac, participation=participation)
        return self

    def strategy(self, name: Union[str, Strategy]) -> "EngineBuilder":
        self._kw["strategy"] = name
        return self

    def optimizer(self, name: Union[str, Optimizer], *, lr: float = None,
                  **opt_kw) -> "EngineBuilder":
        if isinstance(name, str):
            lr = 0.05 if lr is None else lr
            self._kw.update(optimizer=get_optimizer(name, lr, **opt_kw),
                            lr=lr)
        else:
            # a pre-built Optimizer already has its rate baked in; only
            # record lr when the caller states it, so engine.lr never
            # silently disagrees with the update rule
            self._kw["optimizer"] = name
            if lr is not None:
                self._kw["lr"] = lr
        return self

    def data(self, *, alpha: float = 0.5, noise: float = 0.35,
             dataset=None) -> "EngineBuilder":
        self._kw.update(alpha=alpha, noise=noise, data=dataset)
        return self

    def rounds(self, *, local_steps: int = 2, batch_size: int = 16,
               seed: int = 0) -> "EngineBuilder":
        self._kw.update(local_steps=local_steps, batch_size=batch_size,
                        seed=seed)
        return self

    def device_model(self, dm: MET.DeviceModel) -> "EngineBuilder":
        self._kw["device_model"] = dm
        return self

    def execution(self, *, bucketing="ladder", mesh=None,
                  sanitize: bool = False,
                  width_tiers=None,
                  cross_tier: str = "fused") -> "EngineBuilder":
        """Bucket ladder ("ladder" | "exact" | explicit tuple), optional
        mesh for client-axis sharding, the checkify sanitizer mode
        (debug: per-slot NaN/OOB attribution, extra host syncs), an
        optional supernet width ladder (e.g. ``(0.5, 1.0)``) that maps
        client memory budgets to width tiers, and the cross-tier TPGF
        mode ("fused" = one update per mixed-width cohort via
        ``tpgf.fuse_tiers``; "chained" = per-tier sequential chaining)."""
        self._kw.update(bucketing=bucketing, mesh=mesh, sanitize=sanitize,
                        width_tiers=width_tiers, cross_tier=cross_tier)
        return self

    def build(self) -> Engine:
        kw = dict(self._kw)   # builder stays reusable (seed sweeps etc.)
        return Engine(self._cfg, kw.pop("n_clients"), **kw)


@functools.partial(jax.jit, static_argnames=("cfg",))
def predict(cfg: ModelConfig, params, batch):
    Lfull = cfg.split_stack_len
    z, _ = M.prefix_apply(cfg, params, batch, Lfull)
    logits, _ = M.suffix_apply(cfg, params, z, batch, Lfull)
    return logits


@functools.partial(jax.jit, static_argnames=("cfg", "d"))
def local_predict(cfg: ModelConfig, params, batch, d: int):
    """Client-side inference: depth-``d`` prefix + the phi head in
    ``params`` (callers overlay a client's phi_i on the global tree)."""
    z, _ = M.prefix_apply(cfg, params, batch, d)
    return M.local_logits(cfg, params, z)

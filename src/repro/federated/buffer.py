"""Server-side buffered-async update buffer (FedBuff-style).

FedBuff (Nguyen et al., Federated Learning with Buffered Asynchronous
Aggregation) decouples client arrival from server application: client
deltas accumulate in a capacity-``K`` server buffer and the global model
only moves when the buffer flushes, each contribution discounted by how
stale it is. This module is the engine-side realization of that buffer for
the round-based simulator: entries are *cohort* deltas (the granularity
the engine already folds at), tagged with the cohort's staleness and the
round they were pushed in.

The buffer state is a **fixed-shape stacked pytree** — delta slots
``[K, ...]`` over the global parameter tree plus ``[K]`` weight /
staleness / push-round vectors and a fill counter — stored in
``TrainState.opt_state["update_buffer"]``. Fixed shapes are what make it
a first-class citizen of the existing invariants:

  * **checkpointing** — it round-trips through ``TrainState.save`` /
    ``restore`` like any other opt-state slot, so a resumed run replays
    pushes and flushes bit-identically (``Engine.restore`` invalidates
    the strategy's shape-validation cache, mirroring ``_server_opt_ok``);
  * **bounded compile** — pushes and flushes are fixed-shape array ops,
    never data-dependent Python structure;
  * **padded-slot discipline** — unfilled slots carry weight 0 and are
    masked out of every flush reduction, exactly like padded bucket slots.

The flush weighting reuses the *existing* staleness discount
(:func:`repro.federated.strategies.unstable.staleness_weights`): an entry
pushed with staleness ``s`` and flushed ``a`` rounds later weighs
``n_e * (1 + s + a)^-gamma``, renormalized over the filled slots.

Flush policies (:func:`ready`):

  ``"count"``  — flush when the buffer holds >= ``capacity`` entries
                 (FedBuff's K-arrivals rule; the default — the strategy
                 checks after every push, so it fires at exactly K);
  ``"round"``  — flush whenever the buffer is non-empty (synchronous
                 degenerate: every entry applies immediately; with an SGD
                 server optimizer at lr 1.0 and one cohort per round this
                 recovers the ``unstable`` strategy);
  ``"age"``    — flush when the oldest entry is >= ``max_age`` rounds old
                 OR the buffer is full (bounds staleness directly).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SLOT = "update_buffer"   # the TrainState.opt_state key the buffer lives in

POLICIES = ("count", "round", "age")


def init_buffer(template, capacity: int) -> Dict[str, Any]:
    """Fresh buffer state: ``capacity`` zeroed delta slots shaped over
    ``template`` (the global parameter tree; deltas accumulate in fp32),
    per-slot weight / staleness / push-round tags, and a fill counter.
    Traceable (``jax.eval_shape``-able) for cheap shape validation."""
    assert capacity >= 1
    return {
        "deltas": jax.tree.map(
            lambda x: jnp.zeros((capacity,) + x.shape, jnp.float32),
            template),
        "weight": jnp.zeros((capacity,), jnp.float32),
        "staleness": jnp.zeros((capacity,), jnp.float32),
        "round": jnp.zeros((capacity,), jnp.int32),
        "count": jnp.zeros((), jnp.int32),
    }


def capacity_of(buf: Dict[str, Any]) -> int:
    return int(np.shape(buf["weight"])[0])


def fill_count(buf: Dict[str, Any]) -> int:
    return int(np.asarray(buf["count"]))


def push(buf: Dict[str, Any], delta, weight: float, staleness: float,
         round_idx: int) -> Dict[str, Any]:
    """Append one staleness-tagged cohort delta. When the buffer is full
    the OLDEST entry is dropped (ring semantics). The ``async_buffered``
    strategy checks :func:`ready` after every push and every policy fires
    on a full buffer, so the drop branch is a safety net for direct API
    users who push without flushing — the engine path never reaches it.
    Returns the new buffer state (the caller owns the opt-state slot)."""
    k = capacity_of(buf)
    n = fill_count(buf)
    if n >= k:           # drop-oldest: shift everything one slot left
        roll = lambda x: jnp.roll(x, -1, axis=0)
        buf = {"deltas": jax.tree.map(roll, buf["deltas"]),
               "weight": roll(buf["weight"]),
               "staleness": roll(buf["staleness"]),
               "round": roll(buf["round"]),
               "count": buf["count"]}
        n = k - 1
    return {
        "deltas": jax.tree.map(
            lambda b, d: b.at[n].set(d.astype(jnp.float32)),
            buf["deltas"], delta),
        "weight": buf["weight"].at[n].set(jnp.float32(weight)),
        "staleness": buf["staleness"].at[n].set(jnp.float32(staleness)),
        "round": buf["round"].at[n].set(jnp.int32(round_idx)),
        "count": jnp.asarray(n + 1, jnp.int32),
    }


def ready(buf: Dict[str, Any], *, policy: str = "count",
          max_age: int = None, round_idx: int = 0) -> bool:
    """Does the buffer flush now? See the module docstring for policies."""
    if policy not in POLICIES:
        raise ValueError(f"unknown flush policy {policy!r}; "
                         f"available: {POLICIES}")
    n = fill_count(buf)
    if n == 0:
        return False
    if policy == "round":
        return True
    if policy == "age":
        oldest = int(np.min(np.asarray(buf["round"])[:n]))
        if max_age is None:
            raise ValueError("policy='age' requires max_age")
        return (round_idx - oldest) >= max_age or n >= capacity_of(buf)
    return n >= capacity_of(buf)


def flush(buf: Dict[str, Any], *, gamma: float = 1.0,
          round_idx: int = 0) -> Tuple[Any, Dict[str, Any]]:
    """Collapse the filled slots into ONE aggregate delta and reset.

    Each entry's effective staleness is its tag plus its age in the buffer
    (``round_idx - push_round``); entry weights are discounted by the
    standard ``(1 + s)^-gamma`` rule and renormalized over filled slots
    (``staleness_weights`` — the same discount the ``unstable`` strategy
    applies per client). Returns ``(delta_tree, fresh_buffer)``; the delta
    is the convex combination of the buffered cohort deltas, fp32.
    """
    from repro.federated.strategies.unstable import staleness_weights
    n = fill_count(buf)
    if n == 0:
        raise ValueError("flush() on an empty buffer")
    k = capacity_of(buf)
    valid = np.arange(k) < n
    age = round_idx - np.asarray(buf["round"], np.int64)
    eff = np.asarray(buf["staleness"], np.float64) + np.maximum(age, 0)
    w = staleness_weights(np.asarray(buf["weight"]), eff, gamma, mask=valid)
    wj = jnp.asarray(w, jnp.float32)
    delta = jax.tree.map(
        lambda d: jnp.einsum("n,n...->...", wj, d), buf["deltas"])
    fresh = jax.tree.map(jnp.zeros_like, buf)
    return delta, fresh

"""Explicit, functional training state for the federated engine.

``TrainState`` is the single carrier of everything a round mutates:

  params       — the global super-network parameter tree (theta)
  local_heads  — per-client fault-tolerant classifiers phi_i (never
                 aggregated, paper §II-D)
  opt_state    — optimizer state for the pluggable ``repro.optim`` hook
                 (per-round cohort states live inside the strategies; this
                 slot carries anything a strategy wants to persist across
                 rounds — NOT yet checkpointed, see ROADMAP open items)
  round_idx    — completed-round counter
  fleet        — the heterogeneous device fleet (profiles, depths, cohorts)
  rng          — the numpy batch-sampling stream (drawn in a fixed order by
                 the engine so runs are reproducible per seed)

The state is registered as a pytree whose *children* are the array-bearing
fields (params, local_heads, opt_state) — so ``jax.tree.map`` /
``jax.device_get`` traverse it — while fleet / rng / round_idx ride along as
aux data. It is checkpoint-friendly via ``repro.checkpoint``: ``save``
writes a flat npz + manifest, ``restore`` rebuilds the arrays in place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core import supernet as SN
from repro.federated.simulator import Fleet
from repro.models import model as M

Params = Dict[str, Any]


@dataclasses.dataclass
class TrainState:
    params: Params
    local_heads: List[Params]
    opt_state: Any = ()
    round_idx: int = 0
    fleet: Fleet = None
    rng: np.random.Generator = None

    @property
    def n_clients(self) -> int:
        return len(self.local_heads)

    # ------------------------------------------------------------ checkpoint
    # covers params + local_heads + round_idx; opt_state is strategy-shaped
    # and not yet persisted (fleet/rng are reconstructed from the seed)
    def save(self, path: str, *, meta: Dict[str, Any] = None):
        tree = {"params": self.params,
                "local_heads": {str(i): h
                                for i, h in enumerate(self.local_heads)}}
        save_checkpoint(path, tree, step=self.round_idx, meta=meta)

    def restore(self, path: str) -> "TrainState":
        """Load arrays from ``path`` back into this state (in place)."""
        tree, manifest = load_checkpoint(path)
        like = lambda ref, new: jax.tree.map(
            lambda r, n: jax.numpy.asarray(n, r.dtype), ref, new)
        self.params = like(self.params, tree["params"])
        self.local_heads = [like(h, tree["local_heads"][str(i)])
                            for i, h in enumerate(self.local_heads)]
        self.round_idx = int(manifest["step"])
        return self


def _state_flatten(s: TrainState) -> Tuple[tuple, tuple]:
    return ((s.params, s.local_heads, s.opt_state),
            (s.round_idx, s.fleet, s.rng))


def _state_unflatten(aux, children) -> TrainState:
    params, local_heads, opt_state = children
    round_idx, fleet, rng = aux
    return TrainState(params, local_heads, opt_state, round_idx, fleet, rng)


jax.tree_util.register_pytree_node(TrainState, _state_flatten,
                                   _state_unflatten)


def init_train_state(cfg: ModelConfig, n_clients: int, *, seed: int = 0,
                     fleet: Fleet = None) -> TrainState:
    """Fresh state: global params from ``seed``, per-client phi_i from
    ``seed + 1`` (one sub-key per client), batch stream from ``seed``."""
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_clients)
    local_heads = [
        jax.tree.map(lambda x: x + 0.0,
                     {k: v for k, v in SN.split_params(
                         cfg, M.init_params(cfg, kk), 1)[2].items()})
        for kk in keys]
    return TrainState(params=params, local_heads=local_heads,
                      fleet=fleet, rng=np.random.default_rng(seed))

"""Explicit, functional training state for the federated engine.

``TrainState`` is the single carrier of everything a round mutates:

  params       — the global super-network parameter tree (theta)
  local_heads  — per-client fault-tolerant classifiers phi_i (never
                 aggregated, paper §II-D), stored as ONE stacked pytree
                 whose leaves carry a leading ``[N]`` client axis. The
                 stacked layout is what keeps the round loop
                 device-resident: cohort kernels gather their slots'
                 rows, train them, and scatter the results back — no
                 Python list of per-client trees ever crosses the host
                 boundary, and the client axis is shardable
                 (``repro.launch.sharding.fleet_pspecs``).
  opt_state    — cross-round optimizer state, keyed by string slots. The
                 contract: a (possibly nested) dict with string keys and
                 array leaves, so it round-trips through ``repro.checkpoint``
                 unchanged. The built-in split strategies use one slot,
                 ``"server"``: the shared server branch's moments shaped
                 over the FULL branch (d=0 view), sliced per cohort depth
                 (see ``strategies.base.server_opt_state``); FedAvgM uses
                 the same slot for its full-model server momentum. Per-cohort
                 client/local optimizer state is deliberately ephemeral —
                 clients re-download their subnetwork each round.
  round_idx    — completed-round counter
  fleet        — the heterogeneous device fleet (profiles, depths, cohorts)
  rng          — the numpy batch-sampling stream (drawn in a fixed order by
                 the engine so runs are reproducible per seed)

The state is registered as a pytree whose *children* are the array-bearing
fields (params, local_heads, opt_state) — so ``jax.tree.map`` /
``jax.device_get`` traverse it — while fleet / rng / round_idx ride along as
aux data.

Checkpoint format (``save``/``restore`` via ``repro.checkpoint``): one flat
``<path>.npz`` holding ``params/...``, stacked ``local_heads/...`` leaves
(leading client axis) and ``opt_state/...`` leaves, plus a ``<path>.json``
manifest with the round counter (``step``), per-leaf dtypes/shapes, and —
under ``meta.batch_rng`` — the bit-generator state of the batch stream, so
a restored run draws the exact same batches the uninterrupted run would
have. Pre-stacking checkpoints (``local_heads/<i>/...`` with one subtree
per client) are detected structurally on restore — all-digit child keys —
and stacked on the fly. Fleet
profiles are reconstructed from the construction seed, not persisted.
Stateless optimizer slots (plain SGD) flatten to nothing and are lazily
re-initialized after restore.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core import supernet as SN
from repro.federated.simulator import Fleet
from repro.models import model as M

Params = Dict[str, Any]


@dataclasses.dataclass
class TrainState:
    params: Params
    local_heads: Params          # stacked: every leaf is [N, ...]
    opt_state: Dict[str, Any] = dataclasses.field(default_factory=dict)
    round_idx: int = 0
    fleet: Fleet = None
    rng: np.random.Generator = None

    @property
    def n_clients(self) -> int:
        return int(jax.tree.leaves(self.local_heads)[0].shape[0])

    def head_for(self, i: int) -> Params:
        """Client ``i``'s phi_i as an unstacked tree (host-side callers:
        eval ensembles, the FederatedTrainer shim)."""
        return jax.tree.map(lambda x: x[i], self.local_heads)

    # ------------------------------------------------------------ checkpoint
    def save(self, path: str, *, meta: Dict[str, Any] = None):
        """Write ``<path>.npz`` + ``<path>.json`` (format in the module
        docstring). ``meta`` entries are merged into the manifest's meta
        block (``Engine.save`` uses this for its RNG-stream states)."""
        meta = dict(meta or {})
        if self.rng is not None:
            meta["batch_rng"] = self.rng.bit_generator.state
        tree = {"params": self.params,
                "local_heads": self.local_heads,
                "opt_state": self.opt_state}
        save_checkpoint(path, tree, step=self.round_idx, meta=meta)

    def restore(self, path: str) -> "TrainState":
        """Load arrays from ``path`` back into this state (in place):
        params and local_heads are cast onto the existing trees, opt_state
        is adopted wholesale (strategies re-validate its shape lazily), and
        the batch stream resumes from the saved bit-generator state. The
        manifest's meta block is kept on ``self.last_restore_meta`` so
        callers that stored extra state there (``Engine.save``) can read
        it without re-parsing the manifest."""
        tree, manifest = load_checkpoint(path)
        self.last_restore_meta = manifest.get("meta", {})
        like = lambda ref, new: jax.tree.map(
            lambda r, n: jax.numpy.asarray(n, r.dtype), ref, new)
        self.params = like(self.params, tree["params"])
        heads = tree["local_heads"]
        if heads and all(k.isdigit() for k in heads):
            # pre-stacking checkpoint: one subtree per client index
            heads = jax.tree.map(
                lambda *xs: np.stack(xs),
                *[heads[str(i)] for i in range(len(heads))])
        self.local_heads = like(self.local_heads, heads)
        self.opt_state = tree.get("opt_state", {})
        self.round_idx = int(manifest["step"])
        batch_rng = manifest.get("meta", {}).get("batch_rng")
        if batch_rng is not None:
            self.rng = np.random.default_rng()  # fleetlint: disable=FL004 — empty shell; state overwritten next line from the checkpoint
            self.rng.bit_generator.state = batch_rng
        return self


def _state_flatten(s: TrainState) -> Tuple[tuple, tuple]:
    return ((s.params, s.local_heads, s.opt_state),
            (s.round_idx, s.fleet, s.rng))


def _state_unflatten(aux, children) -> TrainState:
    params, local_heads, opt_state = children
    round_idx, fleet, rng = aux
    return TrainState(params, local_heads, opt_state, round_idx, fleet, rng)


jax.tree_util.register_pytree_node(TrainState, _state_flatten,
                                   _state_unflatten)


def init_train_state(cfg: ModelConfig, n_clients: int, *, seed: int = 0,
                     fleet: Fleet = None) -> TrainState:
    """Fresh state: global params from ``seed``, per-client phi_i from
    ``seed + 1`` (one sub-key per client, stacked along the client axis),
    batch stream from ``seed`` — see the RNG-stream contract in
    ``repro.federated.engine``."""
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_clients)
    per_client = [
        {k: v for k, v in SN.split_params(
            cfg, M.init_params(cfg, kk), 1)[2].items()}
        for kk in keys]
    local_heads = jax.tree.map(lambda *xs: jax.numpy.stack(xs), *per_client)
    return TrainState(params=params, local_heads=local_heads,
                      fleet=fleet, rng=np.random.default_rng(seed))

"""Communication-cost, wall-time, and energy accounting.

The paper's Table I/II metrics. Byte counts come from the *actual arrays*
exchanged by each method (no hand-waving): smashed activations, returned
activation gradients, and parameter payloads. Time/energy use a documented
linear device model over the simulated heterogeneity profiles (the paper
itself simulates heterogeneity on homogeneous GPUs).

Device model (defaults; configurable):
  client compute speed  ~ 5 GFLOP/s * (mem_gb / 4)   (weak edge devices)
  server compute speed  = 200 GFLOP/s
  bandwidth             = 20 MB/s per client link
  per-message latency   = lat_i (from the client profile)
  client power          = 5 W active; server power = 250 W active
Energy = power x busy-time, CO2 = energy x 0.4 kg/kWh grid factor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

MB = 1024 * 1024


@dataclasses.dataclass
class DeviceModel:
    client_gflops_per_mem: float = 1.25   # GFLOP/s per GB of memory
    server_gflops: float = 200.0
    bandwidth_mb_s: float = 20.0
    client_power_w: float = 5.0
    server_power_w: float = 250.0
    co2_kg_per_kwh: float = 0.4

    def client_speed(self, mem_gb: float) -> float:
        return self.client_gflops_per_mem * mem_gb * 1e9

    def comm_time_s(self, n_bytes: int, lat_ms: float, n_messages: int = 1
                    ) -> float:
        return n_bytes / (self.bandwidth_mb_s * MB) + n_messages * lat_ms / 1e3


@dataclasses.dataclass
class RoundStats:
    comm_bytes: int = 0
    client_flops: float = 0.0
    server_flops: float = 0.0
    round_time_s: float = 0.0       # max over clients (sync barrier)
    energy_j: float = 0.0
    n_messages: int = 0

    def add(self, other: "RoundStats"):
        self.comm_bytes += other.comm_bytes
        self.client_flops += other.client_flops
        self.server_flops += other.server_flops
        self.round_time_s = max(self.round_time_s, other.round_time_s)
        self.energy_j += other.energy_j
        self.n_messages += other.n_messages


class Accountant:
    """Accumulates per-round stats into a training-run ledger."""

    def __init__(self, device_model: DeviceModel = None):
        self.dm = device_model or DeviceModel()
        self.rounds = []

    def log_round(self, stats: RoundStats):
        self.rounds.append(stats)

    @property
    def total_comm_mb(self) -> float:
        return sum(r.comm_bytes for r in self.rounds) / MB

    @property
    def total_time_s(self) -> float:
        return sum(r.round_time_s for r in self.rounds)

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)

    @property
    def avg_power_w(self) -> float:
        t = self.total_time_s
        return self.total_energy_j / t if t > 0 else 0.0

    def co2_g(self) -> float:
        kwh = self.total_energy_j / 3.6e6
        return kwh * self.dm.co2_kg_per_kwh * 1000.0

    def summary(self) -> Dict[str, float]:
        return {
            "rounds": len(self.rounds),
            "comm_mb": round(self.total_comm_mb, 2),
            "time_s": round(self.total_time_s, 2),
            "energy_j": round(self.total_energy_j, 1),
            "avg_power_w": round(self.avg_power_w, 1),
            "co2_g": round(self.co2_g(), 2),
        }


def tree_bytes(tree) -> int:
    import jax
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def dense_train_flops(n_params: int, n_tokens: int) -> float:
    """6 N D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params * n_tokens

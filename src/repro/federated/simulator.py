"""Heterogeneous-fleet simulation state (profiles, depths, cohorts)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import allocation as AL


@dataclasses.dataclass
class Fleet:
    profiles: List[AL.ClientProfile]
    depths: np.ndarray            # [N] int — allocated subnetwork depths
    capacity: np.ndarray = None   # [N] int — Eq.1 depth the device CAN host
    feasible: np.ndarray = None   # [N] bool — depths[i] <= capacity[i]
    widths: np.ndarray = None     # [N] float — supernet width tier in (0, 1]

    def __post_init__(self):
        if self.capacity is None:
            self.capacity = self.depths.copy()
        if self.feasible is None:
            # a rigid split deeper than the device's Eq.1 capacity cannot be
            # hosted — that client cannot participate (paper §I: "SFL assumes
            # uniform computational capabilities ... unrealistic")
            self.feasible = self.depths <= self.capacity
        if self.widths is None:
            # full-width default: every strategy's width grouping collapses
            # to the single legacy (bit-exact) sub-cohort
            self.widths = np.ones(len(self.profiles), np.float64)

    @property
    def n_clients(self) -> int:
        return len(self.profiles)

    def cohorts(self) -> Dict[int, np.ndarray]:
        """Group FEASIBLE client ids by depth (same depth => same jit)."""
        out: Dict[int, np.ndarray] = {}
        for d in sorted(set(self.depths.tolist())):
            ids = np.where((self.depths == d) & self.feasible)[0]
            if len(ids):
                out[int(d)] = ids
        return out


def make_fleet(cfg: ModelConfig, n_clients: int, *, seed: int = 0,
               fixed_depth: int = None, mem_range=(2.0, 16.0),
               lat_range=(20.0, 200.0)) -> Fleet:
    rng = np.random.default_rng(seed)
    profiles = AL.sample_profiles(n_clients, rng, mem_range=mem_range,
                                  lat_range=lat_range)
    capacity = AL.allocate_for_profiles(
        profiles, cfg.split_stack_len,
        alpha=cfg.alloc_alpha, beta=cfg.alloc_beta)
    capacity = np.minimum(capacity, cfg.split_stack_len - 1).astype(np.int32)
    if fixed_depth is not None:   # SFL baseline: one split point for everyone
        depths = np.full(n_clients, fixed_depth, np.int32)
    else:
        depths = capacity.copy()
    return Fleet(profiles, depths, capacity)

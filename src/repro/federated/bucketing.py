"""Padded cohort buckets: the bounded-compile contract for strategy kernels.

jit specializes on shapes, so a kernel called with a ``[Nc, ...]`` client
stack compiles once per distinct cohort size — and per-round participation
churn (sample_frac, Markov arrivals) plus HASFL re-tuning make Nc different
nearly every round, so compile count grows with the number of *distinct
cohort sizes ever seen*. Bucketing rounds every cohort up to a small ladder
(powers of two by default): a cohort of 5 runs in the size-8 kernel with
three padded slots. Depth is a RUNTIME kernel argument (masked scan over
the full layer stack, ``model.run_stack``), so compile count is
O(widths x buckets) regardless of fleet composition — independent of how
many distinct depth tiers exist or how HASFL re-tuning reshuffles them.

Padded-slot contract (every strategy kernel obeys it):
  * slot ids beyond the real cohort are the SENTINEL ``n_clients`` — an
    out-of-range row index. jax clamps out-of-bounds *gathers* (the slot
    reads some real client's data, which it never publishes) and drops
    out-of-bounds *scatters* (the slot's outputs are discarded), so padding
    needs no masking at the read/write boundary.
  * ``valid`` ([bucket] bool) masks every cross-slot reduction inside the
    kernel: a padded slot contributes zero gradient to the pooled server
    mean, zero loss weight, and — because ``avail`` is forced False on
    padded slots — can never unfreeze the server branch.

Multi-device fleet execution: kernels register as :class:`FleetKernel`
objects that pair the replicated jit with per-mesh ``shard_map`` variants
over the bucket-slot axis. Bucket sizes round up to a multiple of the
fleet-mesh data extent (``bucket_size(..., multiple_of=)``) so every shard
owns whole slots; cross-slot reductions inside kernels go through
:func:`slot_sum` / :func:`masked_slot_mean` / :func:`freeze_gate`, which
``psum`` over the fleet axis when the kernel runs shard-mapped — the same
padded-slot contract holds shard-locally, and the pooled means / freeze
gates see the whole bucket.

Compile accounting: kernels register here (``register_kernel``) and
``kernel_compiles()`` sums their jit cache sizes (replicated + every
sharded variant), so tests and benchmarks can assert the bounded-compile
property directly.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_LADDER: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def bucket_size(n: int, ladder: Sequence[int] = None, *,
                multiple_of: int = 1) -> int:
    """Smallest ladder entry >= ``n`` (doubling past the ladder top).

    ``ladder=None`` means the default power-of-two ladder; an ``"exact"``
    ladder (used by the benchmark's pre-refactor reference mode) is spelled
    ``bucket_size(n, ladder=())`` — no padding, one compile per size.

    ``multiple_of`` rounds the bucket up so it divides evenly into that
    many shards (the fleet-mesh data extent): shard_map needs whole slots
    per shard, and padded slots are a numerical no-op anyway, so a size-5
    cohort on an 8-device fleet mesh runs in a size-8 bucket with one slot
    per device.
    """
    if ladder is None:
        ladder = DEFAULT_LADDER
    b = None
    for cand in ladder:
        if cand >= n:
            b = int(cand)
            break
    if b is None:
        b = int(ladder[-1]) if len(ladder) else n
        while b < n:
            b *= 2
    if multiple_of > 1 and b % multiple_of:
        b += multiple_of - b % multiple_of
    return b


def pad_ids(ids: np.ndarray, bucket: int, n_clients: int) -> np.ndarray:
    """[bucket] int32 ids, padded with the out-of-range sentinel
    ``n_clients`` (dropped by scatters, clamped by gathers)."""
    out = np.full(bucket, n_clients, np.int32)
    out[:len(ids)] = ids
    return out


def pad_rows(arr: np.ndarray, bucket: int, fill=0) -> np.ndarray:
    """Pad axis 0 of a per-slot host array up to ``bucket``."""
    if len(arr) == bucket:
        return arr
    pad = np.full((bucket - len(arr),) + arr.shape[1:], fill, arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def pad_slot_axis(arr: np.ndarray, bucket: int, axis: int) -> np.ndarray:
    """Pad the slot axis of a host array (e.g. [steps, Nc, B] batch
    indices) up to ``bucket`` with zeros (a valid gather index; the data it
    fetches is never used)."""
    if arr.shape[axis] == bucket:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, bucket - arr.shape[axis])
    return np.pad(arr, widths)


# ------------------------------------------------- sharded slot reductions
#
# Every cross-slot reduction inside a strategy kernel goes through these
# helpers. Replicated execution (axis_name=None) reduces over the local
# slot axis only; under a shard-mapped kernel the fleet axis name is bound
# and the local partial reduces ``psum`` across shards, so the result is
# identical-by-construction on every device and the padded-slot contract
# (zero gradient, zero loss weight, cannot unfreeze the server) holds for
# the WHOLE bucket, not just the local shard.

def slot_sum(x, axis_name=None):
    """Sum over the slot axis (0), across all fleet shards."""
    s = jnp.sum(x, axis=0)  # fleetlint: disable=FL002 — this IS the blessed primitive the rule routes to
    return jax.lax.psum(s, axis_name) if axis_name is not None else s


def masked_slot_mean(tree, valid, axis_name=None):
    """Mean of ``tree`` leaves over the VALID slots of the whole bucket.
    ``valid`` is the [local slots] bool mask; padded slots contribute zero
    to the numerator (where, not multiply: NaN-safe) and nothing to the
    denominator."""
    n = slot_sum(valid.astype(jnp.float32), axis_name)

    def mean(g):
        row = valid.reshape((-1,) + (1,) * (g.ndim - 1))
        return slot_sum(jnp.where(row, g, 0.0), axis_name) / n

    return jax.tree.map(mean, tree)


def freeze_gate(avail, valid, axis_name=None):
    """``any(avail & valid)`` over the whole bucket — the server freeze
    gate. A padded slot (valid=False) can never unfreeze the server, on
    any shard."""
    hit = jnp.any(avail & valid)  # fleetlint: disable=FL002 — freeze_gate is the blessed gate; valid already ANDed in
    if axis_name is not None:
        hit = jax.lax.psum(hit.astype(jnp.int32), axis_name) > 0
    return hit


# ------------------------------------------------------------ sanitizer mode

# True only while FleetKernel.sanitized() traces its checkified variant —
# guard_gather reads it at trace time, so the normal jit never carries the
# check ops (and never pays for them).
_SANITIZE_TRACE = False


def guard_gather(idx, size: int, what: str = "batch gather"):
    """Under the sanitizer trace, assert an on-device gather is in bounds.

    jax *clamps* out-of-bounds gathers silently — the padded-slot contract
    depends on that for slot-id gathers, but the batch gather (sample
    indices into the flat dataset) must always be in range, padded slots
    included (``pad_rows`` fills with index 0). ``checkify.index_checks``
    cannot instrument it (its grad-of-gather transpose is broken), so
    kernels call this at the gather site instead; it is a no-op outside
    sanitize mode.
    """
    if _SANITIZE_TRACE:
        from jax.experimental import checkify
        ok = jnp.all((idx >= 0) & (idx < size))  # fleetlint: disable=FL002 — not a slot gate: ANY slot's OOB index (pads included) must trip
        checkify.check(ok, f"{what}: index out of bounds [0, {int(size)})")


class SlotSanitizerError(RuntimeError):
    """A checkify-instrumented kernel tripped a float/index check.

    ``slots`` is the tuple of bucket-slot indices whose outputs came back
    non-finite — the per-slot attribution that turns "a NaN appeared
    somewhere in the cohort" into "client in slot 3 diverged". Empty when
    the failure left no non-finite trace in slot-leading outputs (e.g. an
    out-of-bounds gather caught before it corrupted anything).
    """

    def __init__(self, message: str, slots=()):
        super().__init__(message)
        self.slots = tuple(slots)


def _nonfinite_slots(out, bucket: int):
    """Bucket-slot indices with any non-finite value in a slot-leading
    output leaf. Host-side by design: the sanitizer path trades the
    one-host-sync contract for attribution."""
    bad = set()
    for leaf in jax.tree_util.tree_leaves(out):
        if (getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == bucket
                and np.issubdtype(np.asarray(leaf).dtype, np.floating)):
            rows = np.asarray(leaf).reshape(bucket, -1)
            bad |= {int(i) for i in
                    np.nonzero(~np.isfinite(rows).all(axis=1))[0]}
    return sorted(bad)


def sanitize_failure(err, out, bucket: int, *, kernel: str = "kernel"):
    """Raise :class:`SlotSanitizerError` if the checkify error ``err`` is
    set, attributing the failure to bucket slots via ``out``."""
    msg = err.get()
    if msg is None:
        return
    slots = _nonfinite_slots(out, bucket)
    where = f" (bucket slots {slots})" if slots else ""
    raise SlotSanitizerError(f"sanitizer tripped in {kernel}{where}: {msg}",
                             slots)


# ------------------------------------------------------- compile accounting

_KERNELS: List = []


class FleetKernel:
    """A registered strategy kernel: the replicated jit plus lazily built
    per-mesh ``shard_map`` variants over the bucket-slot axis.

    ``impl(*statics, *arrays, axis_name=None)`` is the pure kernel body:
    the first ``n_static`` positional arguments are jit-static (cfg,
    optimizer, steps, width — depth rides as a runtime array argument),
    the rest are array pytrees whose slot axis (if any)
    is described by ``specs(axes, *arrays) -> (in_specs, out_specs)`` —
    PartitionSpec trees sharding slot-leading axes over the fleet mesh axes
    and replicating shared state (server params, the flat dataset).
    ``axis_name`` is None under the replicated jit and the fleet axis names
    under a sharded variant, so the kernel's cross-slot reductions
    (:func:`slot_sum` & co.) span the whole bucket either way.

    Calling the kernel runs the replicated jit — drop-in for the PR-3
    calling convention; ``Engine.kernel_fn`` picks :meth:`sharded` when a
    fleet mesh with data extent > 1 is configured.
    """

    def __init__(self, impl: Callable, n_static: int, specs: Callable):
        self.impl = impl
        self.n_static = n_static
        self.specs = specs
        self._jit = jax.jit(functools.partial(impl, axis_name=None),
                            static_argnums=tuple(range(n_static)))
        self._sharded = {}
        self._sanitized = None
        functools.update_wrapper(self, impl)

    def __call__(self, *args):
        return self._jit(*args)

    def sharded(self, mesh):
        """The shard-mapped variant for ``mesh`` (cached per mesh)."""
        key = (tuple(d.id for d in mesh.devices.flat), mesh.axis_names)
        fn = self._sharded.get(key)
        if fn is None:
            fn = self._sharded[key] = self._build_sharded(mesh)
        return fn

    def _build_sharded(self, mesh):
        from jax.experimental.shard_map import shard_map
        from repro.launch.sharding import fleet_axes
        axes = fleet_axes(mesh)
        ns, impl, specs = self.n_static, self.impl, self.specs

        @functools.partial(jax.jit, static_argnums=tuple(range(ns)))
        def jitted(*args):
            statics, arrays = args[:ns], args[ns:]
            in_specs, out_specs = specs(axes, *arrays)
            body = functools.partial(impl, *statics, axis_name=axes)
            return shard_map(lambda *a: body(*a), mesh=mesh,
                             in_specs=in_specs, out_specs=out_specs,
                             check_rep=False)(*arrays)

        def run(*args):
            # canonicalize placement BEFORE the jit boundary: the jit
            # cache keys on argument shardings, so round-to-round drift
            # (fresh numpy uploads vs committed outputs of the previous
            # round) would re-specialize the same (width, bucket) program.
            # device_put to the kernel's own specs is a no-op when already
            # placed and keeps the compile count at one per static key.
            statics, arrays = args[:ns], args[ns:]
            in_specs, _ = specs(axes, *arrays)
            return jitted(*statics, *_place(arrays, in_specs, mesh))

        run._cache_size = jitted._cache_size
        return run

    def sanitized(self):
        """The checkify-instrumented replicated jit (built on first use).

        Wraps the pure impl in ``checkify.checkify`` with float checks
        (NaN/inf anywhere in the kernel) and index checks (out-of-bounds
        on the on-device batch gather), so a call returns ``(err, out)``
        instead of ``out``. Always the replicated variant — sanitize mode
        is a debug tool, and checkify's error plumbing does not compose
        with ``shard_map``'s out_specs; under a fleet mesh the sanitizer
        still sees the whole bucket, just on one device.
        """
        if self._sanitized is None:
            from jax.experimental import checkify
            impl = self.impl

            def traced(*args):
                # flag guard_gather sites on for the duration of THIS trace
                global _SANITIZE_TRACE
                prev, _SANITIZE_TRACE = _SANITIZE_TRACE, True
                try:
                    return impl(*args, axis_name=None)
                finally:
                    _SANITIZE_TRACE = prev

            # index_checks is deliberately absent: its instrumentation of
            # the grad-of-gather transpose raises IndexError on the loss
            # gather (take_along_axis under value_and_grad); the explicit
            # guard_gather user check covers the OOB surface instead.
            fn = checkify.checkify(
                traced,
                errors=checkify.float_checks | checkify.user_checks)
            self._sanitized = jax.jit(
                fn, static_argnums=tuple(range(self.n_static)))
        return self._sanitized

    def _cache_size(self) -> int:
        return (self._jit._cache_size()
                + (self._sanitized._cache_size() if self._sanitized else 0)
                + sum(f._cache_size() for f in self._sharded.values()))


def _place(arrays, in_specs, mesh):
    """Device_put the kernel arguments to their PartitionSpecs (each a
    prefix ``P`` covering its whole arg, or a pytree of per-leaf ``P``s)
    in ONE batched transfer."""
    from jax.sharding import NamedSharding, PartitionSpec
    per_arg, shardings = [], []
    for arg, spec in zip(arrays, in_specs):
        leaves, treedef = jax.tree_util.tree_flatten(arg)
        if isinstance(spec, PartitionSpec):
            shardings += [NamedSharding(mesh, spec)] * len(leaves)
        else:
            shardings += [NamedSharding(mesh, s) for s in
                          jax.tree_util.tree_leaves(
                              spec,
                              is_leaf=lambda s: isinstance(s,
                                                           PartitionSpec))]
        per_arg.append((leaves, treedef))
    placed = iter(jax.device_put([x for ls, _ in per_arg for x in ls],
                                 shardings))
    return tuple(jax.tree_util.tree_unflatten(td, [next(placed) for _ in ls])
                 for ls, td in per_arg)


def register_kernel(fn=None, *, n_static: int = 4, specs: Callable = None):
    """Register a strategy kernel for compile accounting.

    Two forms:
      * bare ``@register_kernel`` over an already-jitted function — the
        PR-3 form, replicated execution only;
      * ``@register_kernel(n_static=..., specs=...)`` over a pure impl
        (``axis_name``-aware) — wraps it in a :class:`FleetKernel` whose
        sharded variants ``Engine(mesh=...)`` dispatches to.
    """
    if fn is not None:
        _KERNELS.append(fn)
        return fn

    def deco(impl):
        k = FleetKernel(impl, n_static, specs)
        _KERNELS.append(k)
        return k

    return deco


def kernel_compiles() -> int:
    """Total compiled specializations across all registered kernels (the
    number the bounded-compile tests pin) — replicated jits plus every
    per-mesh sharded variant. Uses the jit cache size, so deltas around a
    run count that run's fresh compiles."""
    return sum(k._cache_size() for k in _KERNELS)

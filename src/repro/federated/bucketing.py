"""Padded cohort buckets: the bounded-compile contract for strategy kernels.

jit specializes on shapes, so a kernel called with a ``[Nc, ...]`` client
stack compiles once per distinct cohort size — and per-round participation
churn (sample_frac, Markov arrivals) plus HASFL re-tuning make Nc different
nearly every round, so compile count grows with the number of *distinct
cohort sizes ever seen*. Bucketing rounds every cohort up to a small ladder
(powers of two by default): a cohort of 5 runs in the size-8 kernel with
three padded slots, so compile count is O(depths x buckets) regardless of
fleet composition, and the compile cache survives HASFL re-tuning.

Padded-slot contract (every strategy kernel obeys it):
  * slot ids beyond the real cohort are the SENTINEL ``n_clients`` — an
    out-of-range row index. jax clamps out-of-bounds *gathers* (the slot
    reads some real client's data, which it never publishes) and drops
    out-of-bounds *scatters* (the slot's outputs are discarded), so padding
    needs no masking at the read/write boundary.
  * ``valid`` ([bucket] bool) masks every cross-slot reduction inside the
    kernel: a padded slot contributes zero gradient to the pooled server
    mean, zero loss weight, and — because ``avail`` is forced False on
    padded slots — can never unfreeze the server branch.

Compile accounting: kernels register here (``register_kernel``) and
``kernel_compiles()`` sums their jit cache sizes, so tests and benchmarks
can assert the bounded-compile property directly.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

DEFAULT_LADDER: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def bucket_size(n: int, ladder: Sequence[int] = None) -> int:
    """Smallest ladder entry >= ``n`` (doubling past the ladder top).

    ``ladder=None`` means the default power-of-two ladder; an ``"exact"``
    ladder (used by the benchmark's pre-refactor reference mode) is spelled
    ``bucket_size(n, ladder=())`` — no padding, one compile per size.
    """
    if ladder is None:
        ladder = DEFAULT_LADDER
    for b in ladder:
        if b >= n:
            return int(b)
    b = int(ladder[-1]) if len(ladder) else n
    while b < n:
        b *= 2
    return b


def pad_ids(ids: np.ndarray, bucket: int, n_clients: int) -> np.ndarray:
    """[bucket] int32 ids, padded with the out-of-range sentinel
    ``n_clients`` (dropped by scatters, clamped by gathers)."""
    out = np.full(bucket, n_clients, np.int32)
    out[:len(ids)] = ids
    return out


def pad_rows(arr: np.ndarray, bucket: int, fill=0) -> np.ndarray:
    """Pad axis 0 of a per-slot host array up to ``bucket``."""
    if len(arr) == bucket:
        return arr
    pad = np.full((bucket - len(arr),) + arr.shape[1:], fill, arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def pad_slot_axis(arr: np.ndarray, bucket: int, axis: int) -> np.ndarray:
    """Pad the slot axis of a host array (e.g. [steps, Nc, B] batch
    indices) up to ``bucket`` with zeros (a valid gather index; the data it
    fetches is never used)."""
    if arr.shape[axis] == bucket:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, bucket - arr.shape[axis])
    return np.pad(arr, widths)


# ------------------------------------------------------- compile accounting

_KERNELS: List = []


def register_kernel(fn):
    """Register a jitted strategy kernel for compile accounting."""
    _KERNELS.append(fn)
    return fn


def kernel_compiles() -> int:
    """Total compiled specializations across all registered kernels (the
    number the bounded-compile tests pin). Uses the jit cache size, so
    deltas around a run count that run's fresh compiles."""
    return sum(k._cache_size() for k in _KERNELS)

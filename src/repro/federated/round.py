"""Federated round orchestration: SuperSFL + the paper's baselines.

Methods:
  ssfl   — the paper: resource-aware depths, TPGF fusion, fault-tolerant
           fallback, Eq.6/8 aggregation.
  sfl    — SplitFed baseline: one fixed split point, server-grad-only client
           updates, plain FedAvg of client prefixes; stalls when the server
           is unreachable.
  dfl    — dynamic-split baseline (Samikwa et al.): resource-aware depths
           like ssfl but server-grad-only (no local classifier/TPGF) and
           depth-weighted FedAvg.
  fedavg — classic FedAvg: full model trained locally, full-model sync.

Clients within a cohort (same depth) are vmapped; the cohort step is jitted
once per (method, depth, cohort size).
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.core.fault import AvailabilityModel
from repro.federated import metrics as MET
from repro.federated.simulator import Fleet, make_fleet
from repro.models import model as M


# --------------------------------------------------------------- cohort steps

@functools.partial(jax.jit, static_argnames=("cfg", "d", "lr", "method"))
def _cohort_step(cfg: ModelConfig, d: int, lr: float, method: str,
                 client_stack, local_stack, server_p, batch_stack, avail):
    """One local step for a cohort of clients sharing depth ``d``.

    client_stack/local_stack: [Nc, ...] stacked client/local param trees.
    server_p: shared server tree. avail: [Nc] bool.
    Returns updated stacks, mean-updated server tree, and per-client losses.
    """

    def one_ssfl(cp, lp, b, av):
        full = SN.merge_params(cfg, cp, server_p, lp)
        out = T.tpgf_grads(cfg, full, b, d, server_available=av)
        gc, gs, gl = SN.split_params(cfg, out.grads, d)
        return gc, gs, gl, out.loss_client, out.loss_server

    fn = one_ssfl
    gc, gs, gl, l_c, l_s = jax.vmap(fn, in_axes=(0, 0, 0, 0))(
        client_stack, local_stack, batch_stack, avail)

    upd = lambda p, g: p - lr * g.astype(p.dtype)
    client_stack = jax.tree.map(upd, client_stack, gc)
    local_stack = jax.tree.map(upd, local_stack, gl)
    # SuperSFL (Alg. 2 line 11): ONE shared main-server model, updated with
    # the cohort's pooled gradient as the smashed batches stream in.
    gs_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)
    server_p = jax.tree.map(upd, server_p, gs_mean)
    return client_stack, local_stack, server_p, l_c, l_s


@functools.partial(jax.jit, static_argnames=("cfg", "d", "lr"))
def _cohort_step_splitfed(cfg: ModelConfig, d: int, lr: float,
                          client_stack, server_stack, local_stack,
                          batch_stack, avail):
    """SplitFedV1-faithful baseline step (SFL/DFL): the server keeps a
    PER-CLIENT server-side copy trained on that client's smashed stream;
    copies are FedAvg'd by the fed server at round end. Client gradients
    come only from the server branch (no local classifier); a stalled
    client (av=False) gets zero update."""

    def one(cp, sp, lp, b, av):
        def loss_fn(cp_, sp_):
            full = SN.merge_params(cfg, cp_, sp_, lp)
            z, _ = M.prefix_apply(cfg, full, b, d)
            return M.server_loss(cfg, full, z, b, d)

        loss, (gc, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        zero = lambda t: jax.tree.map(
            lambda g: jnp.where(av, g, jnp.zeros_like(g)), t)
        return zero(gc), zero(gs), loss

    gc, gs, loss = jax.vmap(one, in_axes=(0, 0, None, 0, 0))(
        client_stack, server_stack, local_stack, batch_stack, avail)
    upd = lambda p, g: p - lr * g.astype(p.dtype)
    return (jax.tree.map(upd, client_stack, gc),
            jax.tree.map(upd, server_stack, gs), loss)


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def _fedavg_step(cfg: ModelConfig, lr: float, params_stack, batch_stack):
    def one(p, b):
        loss, g = jax.value_and_grad(
            lambda pp: M.full_loss(cfg, pp, b))(p)
        return jax.tree.map(lambda x, gg: x - lr * gg.astype(x.dtype), p, g), loss

    return jax.vmap(one)(params_stack, batch_stack)


# ------------------------------------------------------------------- trainer

class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, n_clients: int, method: str = "ssfl",
                 *, seed: int = 0, lr: float = 0.05, local_steps: int = 2,
                 batch_size: int = 16, availability: float = 1.0,
                 data=None, device_model: MET.DeviceModel = None,
                 alpha: float = 0.5, noise: float = 0.35):
        assert method in ("ssfl", "sfl", "dfl", "fedavg")
        self.cfg, self.method = cfg, method
        self.lr, self.local_steps, self.batch_size = lr, local_steps, batch_size
        self.rng = np.random.default_rng(seed)
        # SplitFed's rigid split: one fixed point (mid-stack) for every client
        fixed = max(cfg.split_stack_len // 2, 1) if method == "sfl" else None
        self.fleet: Fleet = make_fleet(cfg, n_clients, seed=seed,
                                       fixed_depth=fixed)
        if method == "fedavg":
            self.fleet.depths[:] = cfg.split_stack_len  # full model local
        self.avail_model = AvailabilityModel(availability, seed=seed + 7)
        from repro.data.synthetic import make_federated_data
        self.data = data or make_federated_data(
            n_clients, n_classes=cfg.n_classes or 10,
            image_size=cfg.image_size, alpha=alpha, seed=seed, noise=noise)
        key = jax.random.PRNGKey(seed)
        self.params = M.init_params(cfg, key)
        # persistent per-client local classifiers (phi_i — never aggregated)
        _, _, local0 = SN.split_params(cfg, self.params, 1)
        keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_clients)
        self.local_heads = [
            jax.tree.map(lambda x: x + 0.0,
                         {k: v for k, v in SN.split_params(
                             cfg, M.init_params(cfg, kk), 1)[2].items()})
            for kk in keys]
        self.accountant = MET.Accountant(device_model)
        self.history: List[Dict] = []

    # ------------------------------------------------------------- one round
    def run_round(self) -> Dict:
        cfg, fleet = self.cfg, self.fleet
        avail = self.avail_model.draw(fleet.n_clients)
        if self.method == "fedavg":
            return self._run_round_fedavg(avail)
        if self.method in ("sfl", "dfl"):
            return self._run_round_splitfed(avail)

        cohorts = fleet.cohorts()
        new_client_trees: List = [None] * fleet.n_clients
        fused_losses = np.zeros(fleet.n_clients)
        stats = MET.RoundStats()
        dm = self.accountant.dm
        server_busy_s = 0.0

        # running server view: full-L split stack + non-stack server leaves
        sname = SN.split_stack_name(cfg)
        server_view = {sname: jax.tree.map(lambda x: x, self.params[sname])}
        for d, ids in cohorts.items():
            client_p, server_p, _ = SN.split_params(cfg, self.params, d)
            cstack = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), client_p)
            lstack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[self.local_heads[i] for i in ids])
            av = jnp.asarray(avail[ids])
            l_c = l_s = None
            for _ in range(self.local_steps):
                batches = [self.data["clients"][i].sample_batch(
                    self.batch_size, self.rng) for i in ids]
                bstack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
                cstack, lstack, server_p, l_c, l_s = _cohort_step(
                    cfg, d, self.lr, self.method, cstack, lstack, server_p,
                    bstack, av)
            # persist local heads + collect client trees for aggregation
            for j, i in enumerate(ids):
                self.local_heads[i] = jax.tree.map(lambda x: x[j], lstack)
                new_client_trees[i] = jax.tree.map(lambda x: x[j], cstack)
                lc, ls = float(l_c[j]), float(l_s[j])
                if self.method == "ssfl" and avail[i]:
                    fused_losses[i] = float(T.fused_loss(
                        lc, ls, d, cfg.split_stack_len - d, cfg.tpgf_eps))
                else:
                    fused_losses[i] = lc if self.method == "ssfl" else ls
            # write server-row updates back into the running server view
            server_view[sname] = jax.tree.map(
                lambda full, nd: jnp.concatenate([full[:d], nd], axis=0),
                server_view[sname], server_p[sname])
            for k, v in server_p.items():
                if k != sname:
                    server_view[k] = v
            # ---- accounting for this cohort
            zbytes = self._smashed_bytes(d)
            if self.method == "ssfl":
                # only the client subnetwork crosses the network (paper §III-C)
                pbytes = SN.client_param_bytes(cfg, self.params, d)
            else:
                # SplitFed aggregates BOTH client- and server-side nets via
                # the fed server each round; DFL coordinates full replicas.
                pbytes = MET.tree_bytes(self.params)
            n_tok = self._tokens_per_batch()
            cparams = sum(int(x.size) for x in jax.tree.leaves(client_p))
            sparams = sum(int(x.size) for x in jax.tree.leaves(server_p))
            for j, i in enumerate(ids):
                prof = fleet.profiles[i]
                up_down = 2 * pbytes  # subnet download + upload per round
                per_step = (2 * zbytes if avail[i] else 0)
                total_b = up_down + self.local_steps * per_step
                # ssfl fallback: no smashed traffic; sfl/dfl stalled: no bytes
                if self.method != "ssfl" and not avail[i]:
                    total_b = 0
                cflops = MET.dense_train_flops(cparams, n_tok) \
                    * self.local_steps
                t = cflops / dm.client_speed(prof.mem_gb) + dm.comm_time_s(
                    total_b, prof.lat_ms,
                    2 + 2 * self.local_steps)
                stats.comm_bytes += total_b
                stats.client_flops += cflops
                stats.round_time_s = max(stats.round_time_s, t)
                stats.energy_j += dm.client_power_w * t
                stats.n_messages += 2 + 2 * self.local_steps
            sflops = MET.dense_train_flops(
                sparams, n_tok) * self.local_steps * len(ids)
            stats.server_flops += sflops
            server_busy_s += sflops / (dm.server_gflops * 1e9)

        stats.round_time_s += server_busy_s
        stats.energy_j += dm.server_power_w * server_busy_s
        # ---- aggregation (Eq. 6 + 8); sfl/dfl use their own weighting
        # infeasible clients (rigid split deeper than device capacity)
        # contributed nothing this round and are excluded
        part = [i for i, t in enumerate(new_client_trees) if t is not None]
        self.params = self._aggregate(
            [new_client_trees[i] for i in part], fused_losses[part],
            server_view, depths=fleet.depths[part])
        self.accountant.log_round(stats)
        rec = {"round": len(self.history) + 1,
               "loss": float(np.mean(fused_losses)),
               **self.accountant.summary()}
        self.history.append(rec)
        return rec

    def _run_round_splitfed(self, avail) -> Dict:
        """SFL/DFL round, SplitFedV1-faithful: per-client server-side copies
        trained on each client's smashed stream, FedAvg'd at round end."""
        cfg, fleet = self.cfg, self.fleet
        cohorts = fleet.cohorts()
        sname = SN.split_stack_name(cfg)
        new_client_trees: List = [None] * fleet.n_clients
        losses = np.zeros(fleet.n_clients)
        stats = MET.RoundStats()
        dm = self.accountant.dm
        server_busy_s = 0.0

        # accumulators for FedAvg over per-client server copies
        num_stack = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                                 self.params[sname])
        den_rows = np.zeros(cfg.split_stack_len)
        num_other: Dict = {}
        den_other = 0

        for d, ids in cohorts.items():
            client_p, server_p, _ = SN.split_params(cfg, self.params, d)
            _, _, local_p = SN.split_params(cfg, self.params, d)
            cstack = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape),
                client_p)
            sstack = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape),
                server_p)
            av = jnp.asarray(avail[ids])
            loss = None
            for _ in range(self.local_steps):
                batches = [self.data["clients"][i].sample_batch(
                    self.batch_size, self.rng) for i in ids]
                bstack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
                cstack, sstack, loss = _cohort_step_splitfed(
                    cfg, d, self.lr, cstack, sstack, local_p, bstack, av)
            for j, i in enumerate(ids):
                new_client_trees[i] = jax.tree.map(lambda x: x[j], cstack)
                losses[i] = float(loss[j])
            # fold this cohort's server copies into the FedAvg accumulators
            num_stack = jax.tree.map(
                lambda acc, s, d=d: acc.at[d:].add(
                    jnp.sum(s.astype(jnp.float32), axis=0)),
                num_stack, sstack[sname])
            den_rows[d:] += len(ids)
            for k, v in sstack.items():
                if k == sname:
                    continue
                add = jax.tree.map(
                    lambda x: jnp.sum(x.astype(jnp.float32), axis=0), v)
                num_other[k] = add if k not in num_other else jax.tree.map(
                    lambda a, b: a + b, num_other[k], add)
            den_other += len(ids)
            # ---- accounting (full-model sync per client: SplitFedV1 ships
            # both client- and server-side nets through the fed server)
            zbytes = self._smashed_bytes(d)
            pbytes = MET.tree_bytes(self.params)
            n_tok = self._tokens_per_batch()
            cparams = sum(int(x.size) for x in jax.tree.leaves(client_p))
            sparams = sum(int(x.size) for x in jax.tree.leaves(server_p))
            for j, i in enumerate(ids):
                prof = fleet.profiles[i]
                total_b = 2 * pbytes + (2 * zbytes * self.local_steps
                                        if avail[i] else 0)
                if not avail[i]:
                    total_b = 0  # stalled: no useful traffic this round
                cflops = MET.dense_train_flops(cparams, n_tok) \
                    * self.local_steps
                t = cflops / dm.client_speed(prof.mem_gb) + dm.comm_time_s(
                    total_b, prof.lat_ms, 2 + 2 * self.local_steps)
                stats.comm_bytes += total_b
                stats.client_flops += cflops
                stats.round_time_s = max(stats.round_time_s, t)
                stats.energy_j += dm.client_power_w * t
                stats.n_messages += 2 + 2 * self.local_steps
            sflops = MET.dense_train_flops(sparams, n_tok) \
                * self.local_steps * len(ids)
            stats.server_flops += sflops
            server_busy_s += sflops / (dm.server_gflops * 1e9)

        stats.round_time_s += server_busy_s
        stats.energy_j += dm.server_power_w * server_busy_s
        # FedAvg the server copies into the server view
        server_view: Dict = {}
        den = jnp.asarray(np.maximum(den_rows, 1e-9))
        avg_stack = jax.tree.map(
            lambda n, g: jnp.where(
                (den_rows > 0).reshape((-1,) + (1,) * (n.ndim - 1)),
                n / den.reshape((-1,) + (1,) * (n.ndim - 1)),
                g.astype(jnp.float32)).astype(g.dtype),
            num_stack, self.params[sname])
        server_view[sname] = avg_stack
        for k, v in num_other.items():
            server_view[k] = jax.tree.map(
                lambda n, g: (n / max(den_other, 1)).astype(g.dtype),
                v, self.params[k])
        part = [i for i, t in enumerate(new_client_trees) if t is not None]
        self.params = self._aggregate(
            [new_client_trees[i] for i in part], losses[part],
            server_view, depths=fleet.depths[part])
        self.accountant.log_round(stats)
        rec = {"round": len(self.history) + 1,
               "loss": float(np.mean(losses[part])) if part else float("nan"),
               **self.accountant.summary()}
        self.history.append(rec)
        return rec

    def _run_round_fedavg(self, avail) -> Dict:
        cfg, fleet = self.cfg, self.fleet
        ids = np.where(avail)[0]
        if len(ids) == 0:
            ids = np.arange(fleet.n_clients)
        pstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), self.params)
        losses = None
        stats = MET.RoundStats()
        dm = self.accountant.dm
        for _ in range(self.local_steps):
            batches = [self.data["clients"][i].sample_batch(
                self.batch_size, self.rng) for i in ids]
            bstack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
            pstack, losses = _fedavg_step(cfg, self.lr, pstack, bstack)
        sizes = np.array([len(self.data["clients"][i].labels) for i in ids],
                         np.float32)
        w = sizes / sizes.sum()
        self.params = jax.tree.map(
            lambda s: jnp.einsum("n,n...->...", jnp.asarray(w),
                                 s.astype(jnp.float32)).astype(s.dtype),
            pstack)
        pbytes = MET.tree_bytes(self.params)
        n_tok = self._tokens_per_batch()
        nparams = sum(int(x.size) for x in jax.tree.leaves(self.params))
        for i in ids:
            prof = fleet.profiles[i]
            t = (MET.dense_train_flops(nparams, n_tok) * self.local_steps
                 / dm.client_speed(prof.mem_gb)
                 + dm.comm_time_s(2 * pbytes, prof.lat_ms, 2))
            stats.comm_bytes += 2 * pbytes
            stats.client_flops += MET.dense_train_flops(
                nparams, n_tok) * self.local_steps
            stats.round_time_s = max(stats.round_time_s, t)
            stats.energy_j += dm.client_power_w * t
            stats.n_messages += 2
        self.accountant.log_round(stats)
        rec = {"round": len(self.history) + 1,
               "loss": float(np.mean(np.asarray(losses))),
               **self.accountant.summary()}
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------ aggregation
    def _aggregate(self, client_trees, losses, server_view, depths=None):
        cfg = self.cfg
        depths = self.fleet.depths if depths is None else depths
        # global tree with this round's server-side training folded in
        globals_with_server = dict(self.params)
        globals_with_server.update(server_view)
        stacked = AGG.stack_client_trees(cfg, client_trees, depths)
        if self.method == "ssfl":
            new_params, _ = AGG.aggregate(cfg, globals_with_server, stacked,
                                          depths, losses)
            return new_params
        # sfl: plain FedAvg (uniform); dfl: depth-weighted average
        n = len(client_trees)
        if self.method == "dfl":
            w = jnp.asarray(depths.astype(np.float32) / depths.sum())
        else:
            w = jnp.full(n, 1.0 / n, jnp.float32)
        pres = AGG.presence_mask(depths, cfg.split_stack_len)
        sname = SN.split_stack_name(cfg)
        new_params = dict(globals_with_server)
        for key, leaf_tree in stacked.items():
            pm = pres if key == sname else None
            new_params[key] = jax.tree.map(
                lambda c, s, pm=pm: AGG._agg_leaf(c, s, w, pm,
                                                  cfg.agg_lambda),
                leaf_tree, globals_with_server[key])
        return new_params

    # -------------------------------------------------------------- utilities
    def _tokens_per_batch(self) -> int:
        cfg = self.cfg
        if cfg.family == "vit":
            return self.batch_size * (cfg.image_size // cfg.patch_size) ** 2
        return self.batch_size * 128

    def _smashed_bytes(self, d: int) -> int:
        cfg = self.cfg
        toks = self._tokens_per_batch()
        return toks * cfg.d_model * 4  # fp32 activations

    def evaluate(self, max_batches: int = 8) -> float:
        cfg = self.cfg
        test = self.data["test"]
        bs = 64
        correct = total = 0
        for i in range(0, min(len(test.labels), max_batches * bs), bs):
            batch = {"images": jnp.asarray(test.images[i:i + bs]),
                     "label": jnp.asarray(test.labels[i:i + bs])}
            logits = predict(cfg, self.params, batch)
            pred = np.asarray(jnp.argmax(logits, -1))
            correct += int((pred == test.labels[i:i + bs]).sum())
            total += len(pred)
        return correct / max(total, 1)

    def train(self, n_rounds: int, *, eval_every: int = 5,
              target_accuracy: float = None, verbose: bool = False):
        for r in range(n_rounds):
            rec = self.run_round()
            if (r + 1) % eval_every == 0 or r == n_rounds - 1:
                rec["accuracy"] = self.evaluate()
                if verbose:
                    print(f"[{self.method}] round {rec['round']} "
                          f"loss={rec['loss']:.3f} acc={rec['accuracy']:.3f}")
                if target_accuracy and rec["accuracy"] >= target_accuracy:
                    return rec
        return self.history[-1]


@functools.partial(jax.jit, static_argnames=("cfg",))
def predict(cfg: ModelConfig, params, batch):
    Lfull = cfg.split_stack_len
    z, _ = M.prefix_apply(cfg, params, batch, Lfull)
    logits, _ = M.suffix_apply(cfg, params, z, batch, Lfull)
    return logits

"""Back-compat shim: the seed's ``FederatedTrainer`` API on the new engine.

The monolithic trainer (one ~100-line branch per method) was split into
``repro.federated.state`` (TrainState), ``repro.federated.strategies``
(the Strategy registry: ssfl / sfl / dfl / fedavg) and
``repro.federated.engine`` (the single ``Engine.run_round`` code path).
This module keeps the old constructor and attribute surface working for
existing examples, benchmarks and tests; new code should use ``Engine``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig
from repro.federated import metrics as MET
from repro.federated.engine import Engine, predict  # noqa: F401


class FederatedTrainer:
    """Thin delegate around :class:`repro.federated.engine.Engine`."""

    def __init__(self, cfg: ModelConfig, n_clients: int, method: str = "ssfl",
                 *, seed: int = 0, lr: float = 0.05, local_steps: int = 2,
                 batch_size: int = 16, availability: float = 1.0,
                 data=None, device_model: MET.DeviceModel = None,
                 alpha: float = 0.5, noise: float = 0.35):
        assert method in ("ssfl", "sfl", "dfl", "fedavg")
        self.engine = Engine(cfg, n_clients, strategy=method, seed=seed,
                             lr=lr, local_steps=local_steps,
                             batch_size=batch_size, availability=availability,
                             data=data, device_model=device_model,
                             alpha=alpha, noise=noise)

    # ------------------------------------------------- delegated attributes
    @property
    def cfg(self) -> ModelConfig:
        return self.engine.cfg

    @property
    def lr(self) -> float:
        return self.engine.lr

    @property
    def local_steps(self) -> int:
        return self.engine.local_steps

    @property
    def batch_size(self) -> int:
        return self.engine.batch_size

    @property
    def rng(self):
        return self.engine.state.rng

    @property
    def method(self) -> str:
        return self.engine.strategy.name

    @property
    def fleet(self):
        return self.engine.state.fleet

    @property
    def params(self):
        return self.engine.state.params

    @params.setter
    def params(self, value):
        self.engine.state.params = value

    @property
    def local_heads(self) -> List:
        """Seed-era surface: a list of per-client phi_i trees (the state
        itself stores them stacked along a leading client axis)."""
        state = self.engine.state
        return [state.head_for(i) for i in range(state.n_clients)]

    @property
    def accountant(self) -> MET.Accountant:
        return self.engine.accountant

    @property
    def history(self) -> List[Dict]:
        return self.engine.history

    @property
    def data(self):
        return self.engine.data

    @property
    def avail_model(self):
        return self.engine.avail_model

    # --------------------------------------------------- delegated behaviour
    def run_round(self) -> Dict:
        return self.engine.run_round()

    def evaluate(self, max_batches: int = 8) -> float:
        return self.engine.evaluate(max_batches)

    def train(self, n_rounds: int, *, eval_every: int = 5,
              target_accuracy: float = None, verbose: bool = False):
        return self.engine.train(n_rounds, eval_every=eval_every,
                                 target_accuracy=target_accuracy,
                                 verbose=verbose)

"""Classic FedAvg as an engine strategy: full model trained locally,
data-size-weighted full-model sync. No split, no server compute."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.federated import metrics as MET
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.models import model as M
from repro.optim import apply_updates


@functools.partial(jax.jit, static_argnames=("cfg", "opt"))
def step_kernel(cfg: ModelConfig, opt, params_stack, batch_stack, opt_state):
    def one(p, b):
        return jax.value_and_grad(lambda pp: M.full_loss(cfg, pp, b))(p)

    losses, grads = jax.vmap(one)(params_stack, batch_stack)
    updates, opt_state = opt.update(grads, opt_state, params_stack)
    return apply_updates(params_stack, updates), opt_state, losses


@register_strategy("fedavg")
class FedAvg(Strategy):

    def prepare_fleet(self, cfg, fleet, device_model=None) -> None:
        fleet.depths[:] = cfg.split_stack_len   # full model local

    def cohorts(self, engine, ctx: RoundContext):
        """One cohort of every available sampled client (all-full-depth);
        if nobody is reachable the round degrades to everyone-local."""
        ids = np.where(ctx.avail & ctx.participants)[0]
        if len(ids) == 0:   # _draw_participants guarantees >= 1 sampled
            ids = np.where(ctx.participants)[0]
        if len(ids) == 0:   # an arrival process may leave nobody at all
            return {}
        return {engine.cfg.split_stack_len: ids}

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        return {"ids": None, "pstack": None, "losses": None}

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        state = engine.state
        pstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape),
            state.params)
        opt_state = engine.optimizer.init(pstack)
        losses = None
        for _ in range(engine.local_steps):
            bstack = ctx.batch_fn(ids)
            pstack, opt_state, losses = step_kernel(
                engine.cfg, engine.optimizer, pstack, bstack, opt_state)
        ws["ids"], ws["pstack"], ws["losses"] = ids, pstack, losses
        nparams = sum(int(x.size) for x in jax.tree.leaves(state.params))
        return CohortResult(nparams, 0)

    def aggregate(self, engine, ws):
        ids, pstack = ws["ids"], ws["pstack"]
        if ids is None:   # nobody arrived this round (participation process)
            return engine.state.params, float("nan")
        sizes = np.array(
            [len(engine.data["clients"][i].labels) for i in ids], np.float32)
        w = sizes / sizes.sum()
        new_params = jax.tree.map(
            lambda s: jnp.einsum("n,n...->...", jnp.asarray(w),
                                 s.astype(jnp.float32)).astype(s.dtype),
            pstack)
        return new_params, float(np.mean(np.asarray(ws["losses"])))

    def comm_cost(self, engine, d, available):
        return 2 * MET.tree_bytes(engine.state.params), 2

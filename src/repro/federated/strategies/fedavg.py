"""Classic FedAvg as an engine strategy: full model trained locally,
data-size-weighted full-model sync. No split, no server compute.

The FedOpt family (Reddi et al., Adaptive Federated Optimization) rides on
the same fold: the round's data-weighted average is treated as a
pseudo-gradient ``theta_old - theta_avg`` and folded through a pluggable
*server* optimizer whose moments persist across rounds (and checkpoints)
in the same ``TrainState.opt_state["server"]`` slot the split strategies
use. ``fedavgm`` is the heavy-ball member (Hsu et al.); ``fedadam`` and
``fedyogi`` are the adaptive members (``repro.optim.fedadam`` /
``fedyogi`` — Adam / Yogi second moments without bias correction, tau
= 1e-3). All three resume bit-identically from a checkpoint.

Execution follows the bucketed device-resident kernel contract: one
scanned kernel per bucket runs all local steps with on-device batch
gather; padded slots train throwaway copies that the size-weighted
aggregation zeroes out.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.federated import bucketing as BK
from repro.federated import metrics as MET
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.launch.sharding import P, slot_pspec
from repro.models import model as M
from repro.optim import (Optimizer, apply_updates, fedadam, fedyogi,
                         sgd_momentum)


def _step_specs(axes, params_stack, images, labels, idx):
    """shard_map layout: slots are fully independent in FedAvg, so only
    the param stack and index slot axes shard; no cross-shard collectives
    are needed at all."""
    slot = slot_pspec(0, axes)
    return ((slot, P(), P(), slot_pspec(1, axes)), (slot, slot))


@BK.register_kernel(n_static=3, specs=_step_specs)
def step_kernel(cfg: ModelConfig, opt, steps: int, params_stack,
                images, labels, idx, axis_name=None):
    """All ``steps`` full-model local steps for one padded bucket, scanned,
    with on-device batch gather. Slots are independent (classic FedAvg), so
    padded slots simply train a throwaway copy that aggregation ignores —
    and the shard-mapped variant (``axis_name`` bound) needs no
    collectives."""

    def one(p, b):
        return jax.value_and_grad(lambda pp: M.full_loss(cfg, pp, b))(p)

    def step(carry, idx_t):
        pstack, opt_state = carry
        BK.guard_gather(idx_t, images.shape[0])   # sanitize-mode OOB check
        batch = {"images": images[idx_t], "label": labels[idx_t]}
        losses, grads = jax.vmap(one)(pstack, batch)
        updates, opt_state = opt.update(grads, opt_state, pstack)
        return (apply_updates(pstack, updates), opt_state), losses

    carry = (params_stack, opt.init(params_stack))
    (pstack, _), losses = jax.lax.scan(step, carry, idx)
    return pstack, losses[-1]


@register_strategy("fedavg")
class FedAvg(Strategy):
    """server_momentum=0 and server_opt=None is exact FedAvg (the server
    fold is skipped entirely, not applied with a unit step — float-identical
    to the plain average). ``fedavgm`` registers heavy-ball momentum at the
    0.9 default; ``fedadam`` / ``fedyogi`` register the adaptive FedOpt
    members. Any ``repro.optim.Optimizer`` can be passed as ``server_opt``
    — it receives the pseudo-gradient ``theta_old - theta_avg`` once per
    round and its state persists in ``opt_state["server"]``."""

    def __init__(self, server_momentum: float = 0.0,
                 server_opt: Optimizer = None):
        assert not (server_momentum and server_opt is not None), \
            "pass either server_momentum or an explicit server_opt"
        self.server_momentum = server_momentum
        # pseudo-gradient step: mu <- beta*mu + (old - avg); p <- p - mu
        self._server_opt = server_opt if server_opt is not None else (
            sgd_momentum(1.0, server_momentum) if server_momentum else None)

    def prepare_fleet(self, cfg, fleet, device_model=None) -> None:
        fleet.depths[:] = cfg.split_stack_len   # full model local

    def cohorts(self, engine, ctx: RoundContext):
        """One cohort of every available sampled client (all-full-depth);
        if nobody is reachable the round degrades to everyone-local."""
        ids = np.where(ctx.avail & ctx.participants)[0]
        if len(ids) == 0:   # _draw_participants guarantees >= 1 sampled
            ids = np.where(ctx.participants)[0]
        if len(ids) == 0:   # an arrival process may leave nobody at all
            return {}
        return {engine.cfg.split_stack_len: ids}

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        return {"ids": None, "pstack": None, "valid": None, "losses": None}

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        state = engine.state
        bucket = engine.bucket_for(len(ids))
        idx = jnp.asarray(BK.pad_slot_axis(
            ctx.sample_indices(ids, engine.local_steps, engine.batch_size),
            bucket, axis=1))
        pstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (bucket,) + x.shape),
            state.params)
        dd = engine.device_data
        kernel = engine.kernel_fn(step_kernel, bucket)
        pstack, losses = kernel(engine.cfg, engine.optimizer,
                                engine.local_steps, pstack,
                                dd.images, dd.labels, idx)
        ws["ids"], ws["pstack"], ws["losses"] = ids, pstack, losses
        ws["valid"] = np.arange(bucket) < len(ids)
        nparams = sum(int(x.size) for x in jax.tree.leaves(state.params))
        return CohortResult(nparams, 0, losses=losses)

    def aggregate(self, engine, ws):
        ids, pstack = ws["ids"], ws["pstack"]
        if ids is None:   # nobody arrived this round (participation process)
            return engine.state.params, float("nan")
        # data-size weights over real slots; padded slots weigh 0, so their
        # throwaway contents never reach the average
        sizes = np.zeros(len(ws["valid"]), np.float32)
        sizes[:len(ids)] = [len(engine.data["clients"][i].labels)
                            for i in ids]
        w = sizes / sizes.sum()
        avg = jax.tree.map(
            lambda s: jnp.einsum("n,n...->...", jnp.asarray(w),
                                 s.astype(jnp.float32)).astype(s.dtype),
            pstack)
        loss = float(np.mean(np.asarray(ws["losses"])[ws["valid"]]))
        if self._server_opt is None:
            return avg, loss
        return self._server_fold(engine, avg), loss

    def _server_fold(self, engine, avg):
        """FedOpt: fold the round average through the persistent server
        optimizer — heavy-ball (FedAvgM), Adam (FedAdam) or Yogi (FedYogi)
        — lazily (re)initialized when absent or shape-mismatched, e.g.
        after a restore from a different run. Validation runs once per
        (engine, optimizer) and after every ``Engine.restore`` — the same
        ``_server_opt_ok`` discipline as ``base.server_opt_state``."""
        params = engine.state.params
        cur = engine.state.opt_state.get("server")
        opt_id = id(self._server_opt)
        if cur is None or getattr(engine, "_server_opt_ok",
                                  None) != opt_id:
            want = jax.eval_shape(self._server_opt.init, params)
            if cur is None or not base._state_like(cur, want):
                cur = self._server_opt.init(params)
            engine._server_opt_ok = opt_id
        delta = jax.tree.map(
            lambda old, new: (old.astype(jnp.float32)
                              - new.astype(jnp.float32)), params, avg)
        updates, cur = self._server_opt.update(delta, cur, params)
        engine.state.opt_state["server"] = cur
        return apply_updates(params, updates)

    def comm_cost(self, engine, d, available, ids=None):
        return 2 * MET.tree_bytes(engine.state.params), 2


@register_strategy("fedavgm")
class FedAvgM(FedAvg):
    """FedAvg + 0.9 server momentum (Hsu et al., 2019)."""

    def __init__(self, server_momentum: float = 0.9):
        super().__init__(server_momentum=server_momentum)


@register_strategy("fedadam")
class FedAdam(FedAvg):
    """FedAvg + server-side Adam on the round pseudo-gradient (Reddi et
    al., 2021). ``server_lr`` is eta_s; the 1e-3 tau bounds adaptivity."""

    def __init__(self, server_lr: float = 0.1, b1: float = 0.9,
                 b2: float = 0.99, eps: float = 1e-3):
        super().__init__(server_opt=fedadam(server_lr, b1=b1, b2=b2,
                                            eps=eps))


@register_strategy("fedyogi")
class FedYogi(FedAvg):
    """FedAvg + server-side Yogi (Reddi et al., 2021): Adam's first
    moment, Yogi's additive second-moment rule — slower variance decay
    under the sparse, bursty pseudo-gradients of partial participation."""

    def __init__(self, server_lr: float = 0.1, b1: float = 0.9,
                 b2: float = 0.99, eps: float = 1e-3):
        super().__init__(server_opt=fedyogi(server_lr, b1=b1, b2=b2,
                                            eps=eps))

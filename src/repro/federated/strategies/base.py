"""The Strategy protocol: what a federated method must supply.

The ``Engine`` owns everything method-independent — arrival/availability
draws, client sampling, staleness tracking, batch RNG, cohorting, the
metrics ``Accountant``, history and eval. A ``Strategy`` supplies only the
method-specific pieces:

  init_round   — allocate the per-round workspace (server views, FedAvg
                 accumulators, loss buffers)
  cohort_step  — run ``local_steps`` updates for one same-depth cohort,
                 recording client trees / losses into the workspace
  fold_server  — fold a cohort's server-side result into the running
                 server view / accumulators
  aggregate    — produce the next global params + the round's loss scalar
  comm_cost    — per-client bytes and message count for the round

so the accounting that the seed trainer duplicated three times lives in
exactly one place (``Engine._account_cohort``).

Strategies register with ``@register_strategy("name")`` and are resolved by
``get_strategy(name)``; anything matching the protocol can be passed to the
engine directly, so new scenarios (unstable participation, co-tuned splits)
are a new module, not a new copy of the trainer. ``docs/strategies.md``
walks through the protocol hook by hook with ``unstable`` as the worked
example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.core.fault import ArrivalProcess
from repro.optim import map_moments


@dataclasses.dataclass
class RoundContext:
    """Engine-drawn randomness + bookkeeping for one round.

    avail        — [N] bool, server reachable this round (drawn from the
                   engine's availability :class:`ArrivalProcess`)
    participants — [N] bool, client showed up: the intersection of the
                   ``sample_frac`` draw and the participation arrival
                   process (all-True when neither is configured)
    batch_fn     — ids -> stacked batch; accepts an optional ``batch_size``
                   keyword for strategies that co-tune per-client batches
    staleness    — [N] int, rounds each client has been absent since it
                   last participated (0 for a client seen last round and
                   for everyone in round 0); engine-owned, used by
                   staleness-weighted aggregation
    """
    avail: np.ndarray
    participants: np.ndarray
    batch_fn: Callable[..., Any]
    staleness: np.ndarray = None


@dataclasses.dataclass
class CohortResult:
    """What ``cohort_step`` hands back for accounting + server folding."""
    client_params: int           # per-client trainable param count
    server_params: int           # server-side param count (0 => no server)
    payload: Any = None          # strategy-private, consumed by fold_server
    tokens_per_batch: int = None  # effective per-step tokens when a strategy
    #                               tunes batch sizes (None => engine default)


class Strategy:
    """Base: shared hooks with no-op defaults. Subclasses implement the
    four round phases; ``name`` is set by ``@register_strategy``."""

    name: str = "?"

    # ---------------------------------------------------- fleet construction
    def fixed_depth(self, cfg) -> int | None:
        """A rigid split point for every client, or None for Eq.1 depths."""
        return None

    def prepare_fleet(self, cfg, fleet, device_model=None) -> None:
        """Post-allocation fleet adjustment (e.g. FedAvg trains the full
        model locally; HASFL records the device model for co-tuning)."""

    def participation_process(self, cfg, n_clients: int,
                              seed: int) -> Optional[ArrivalProcess]:
        """An :class:`ArrivalProcess` governing which clients show up each
        round, or None for always-on participation. The engine prefers an
        explicitly passed ``participation=`` process over this default."""
        return None

    # ------------------------------------------------------------- cohorting
    def cohorts(self, engine, ctx: RoundContext) -> Dict[int, np.ndarray]:
        """Feasible same-depth cohorts, restricted to sampled participants."""
        out: Dict[int, np.ndarray] = {}
        for d, ids in engine.state.fleet.cohorts().items():
            ids = ids[ctx.participants[ids]]
            if len(ids):
                out[d] = ids
        return out

    # ---------------------------------------------------------- round phases
    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        raise NotImplementedError

    def cohort_step(self, engine, ctx: RoundContext, ws: Dict[str, Any],
                    d: int, ids: np.ndarray) -> CohortResult:
        raise NotImplementedError

    def fold_server(self, engine, ws: Dict[str, Any], d: int,
                    ids: np.ndarray, res: CohortResult) -> None:
        pass

    def aggregate(self, engine, ws: Dict[str, Any]) -> Tuple[Any, float]:
        """-> (new global params, round loss scalar)."""
        raise NotImplementedError

    def _finish_aggregation(self, engine, ws: Dict[str, Any],
                            server_view: Dict[str, Any],
                            agg_fn: Callable) -> Tuple[Any, float]:
        """Shared aggregation tail: filter the clients that actually trained
        (infeasible / unsampled ones contributed nothing), merge this
        round's server view into the globals, stack the client trees, and
        delegate the weighting to ``agg_fn(globals, stacked, depths,
        losses)``. The participating ids land in ``ws["participated"]`` so
        scenario weightings (e.g. staleness) can line up per-client data
        with the stacked trees. Returns (new params, mean participant
        loss)."""
        state = engine.state
        trees, losses = ws["client_trees"], ws["losses"]
        part = [i for i, t in enumerate(trees) if t is not None]
        if not part:   # e.g. every sampled client infeasible this round
            return state.params, float("nan")
        ws["participated"] = np.asarray(part)
        depths = state.fleet.depths[part]
        globals_with_server = dict(state.params)
        globals_with_server.update(server_view)
        stacked = AGG.stack_client_trees(engine.cfg,
                                         [trees[i] for i in part], depths)
        new_params = agg_fn(globals_with_server, stacked, depths,
                            losses[part])
        return new_params, float(np.mean(losses[part]))

    # ------------------------------------------------------------ accounting
    def comm_cost(self, engine, d: int, available: bool) -> Tuple[int, int]:
        """-> (total bytes on the wire this round, messages) per client."""
        raise NotImplementedError


# ----------------------------------------------- persistent server opt state
#
# The shared server branch's optimizer state lives in
# ``TrainState.opt_state["server"]``, shaped over the FULL server branch
# (the d=0 view: whole split stack + non-stack server leaves) so it is
# independent of which cohort depths exist in a given round. Each cohort
# slices rows [d:] out of the moment stacks, runs its local steps, and
# writes the rows back — mirroring exactly how ``fold_server`` streams
# cohort server views into the round's running view (Alg. 2 line 11).
# ``repro.optim.map_moments`` keeps all of this optimizer-agnostic.

def server_opt_state(engine, template) -> Any:
    """The persistent full-server-branch optimizer state, lazily
    initialized (and re-initialized if the stored state does not match the
    current optimizer/model — e.g. after switching optimizers between a
    save and a restore). The shape validation runs once per (engine,
    optimizer) and after every ``Engine.restore``, not on every cohort;
    adopt external state through ``Engine.restore`` so it is re-checked."""
    cur = engine.state.opt_state.get("server")
    opt_id = id(engine.optimizer)
    if cur is not None and getattr(engine, "_server_opt_ok", None) == opt_id:
        return cur
    want = jax.eval_shape(engine.optimizer.init, template)
    if cur is None or not _state_like(cur, want):
        cur = engine.optimizer.init(template)
        engine.state.opt_state["server"] = cur
    engine._server_opt_ok = opt_id
    return cur


def cohort_server_opt(engine, cfg, sname: str, d: int):
    """The cohort-step prologue every split strategy shares: fetch the
    persistent full-branch state and slice this cohort's depth-``d`` view.
    Returns ``(srv_template, srv_full, srv_state)``; after stepping, hand
    ``srv_state`` back through :func:`merge_server_opt`."""
    srv_template = SN.split_params(cfg, engine.state.params, 0)[1]
    srv_full = server_opt_state(engine, srv_template)
    return (srv_template, srv_full,
            slice_server_opt(srv_full, srv_template, sname, d))


def _state_like(state, shaped) -> bool:
    if jax.tree_util.tree_structure(state) != \
            jax.tree_util.tree_structure(shaped):
        return False
    return all(tuple(np.shape(a)) == tuple(b.shape)
               for a, b in zip(jax.tree.leaves(state),
                               jax.tree.leaves(shaped)))


def slice_server_opt(state, template, sname: str, d: int):
    """Project the depth-``d`` cohort's server slice out of the full-branch
    state: moment stack rows ``[d:]``, non-stack moments and bookkeeping
    whole. ``template`` is the full server params tree (structure probe)."""
    def sl(tree):
        out = {k: v for k, v in tree.items() if k != sname}
        out[sname] = jax.tree.map(lambda x: x[d:], tree[sname])
        return out
    return map_moments(sl, state, template)


def merge_server_opt(full, cohort, template, sname: str, d: int):
    """Write a cohort's post-update server slice back into the full-branch
    state. Stack moment rows ``[d:]`` are replaced; non-stack moments and
    bookkeeping (step counters) take the cohort's values — last cohort
    wins, mirroring the server-view fold."""
    if not isinstance(full, dict):
        return full
    pdef = jax.tree_util.tree_structure(template)
    out = {}
    for k, v in full.items():
        cv = cohort[k]
        if jax.tree_util.tree_structure(v) == pdef:
            merged = {kk: vv for kk, vv in cv.items() if kk != sname}
            merged[sname] = jax.tree.map(
                lambda f, c: jnp.concatenate([f[:d], c], axis=0),
                v[sname], cv[sname])
            out[k] = merged
        else:
            out[k] = cv
    return out


def broadcast_server_opt(state, template, n: int):
    """Stack a server opt-state slice along a new leading client axis
    (SplitFed trains per-client server copies; each starts the round from
    the shared fed-averaged moments)."""
    return map_moments(
        lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape), t),
        state, template)


def mean_server_opt(state, template):
    """Collapse per-client server moments back to the shared state by
    averaging over the leading client axis (the moment-space analogue of
    SplitFed's round-end FedAvg over server copies)."""
    return map_moments(
        lambda t: jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
            t),
        state, template)


# ----------------------------------------------------------------- registry

_REGISTRY: Dict[str, Type[Strategy]] = {}


def register_strategy(name: str):
    def deco(cls: Type[Strategy]) -> Type[Strategy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> Strategy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"available: {available_strategies()}")
    return _REGISTRY[name]()


def available_strategies():
    return sorted(_REGISTRY)

"""The Strategy protocol: what a federated method must supply.

The ``Engine`` owns everything method-independent — availability draws,
client sampling, batch RNG, cohorting, the metrics ``Accountant``, history
and eval. A ``Strategy`` supplies only the method-specific pieces:

  init_round   — allocate the per-round workspace (server views, FedAvg
                 accumulators, loss buffers)
  cohort_step  — run ``local_steps`` updates for one same-depth cohort,
                 recording client trees / losses into the workspace
  fold_server  — fold a cohort's server-side result into the running
                 server view / accumulators
  aggregate    — produce the next global params + the round's loss scalar
  comm_cost    — per-client bytes and message count for the round

so the accounting that the seed trainer duplicated three times lives in
exactly one place (``Engine._account_cohort``).

Strategies register with ``@register_strategy("name")`` and are resolved by
``get_strategy(name)``; anything matching the protocol can be passed to the
engine directly, so new scenarios (unstable participation, co-tuned splits)
are a new module, not a new copy of the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Sequence, Tuple, Type

import numpy as np

from repro.core import aggregation as AGG


@dataclasses.dataclass
class RoundContext:
    """Engine-drawn randomness for one round, shared across strategies."""
    avail: np.ndarray            # [N] bool — server reachable this round
    participants: np.ndarray     # [N] bool — sampled into the round
    batch_fn: Callable[[Sequence[int]], Any]   # ids -> stacked batch


@dataclasses.dataclass
class CohortResult:
    """What ``cohort_step`` hands back for accounting + server folding."""
    client_params: int           # per-client trainable param count
    server_params: int           # server-side param count (0 => no server)
    payload: Any = None          # strategy-private, consumed by fold_server


class Strategy:
    """Base: shared hooks with no-op defaults. Subclasses implement the
    four round phases; ``name`` is set by ``@register_strategy``."""

    name: str = "?"

    # ---------------------------------------------------- fleet construction
    def fixed_depth(self, cfg) -> int | None:
        """A rigid split point for every client, or None for Eq.1 depths."""
        return None

    def prepare_fleet(self, cfg, fleet) -> None:
        """Post-allocation fleet adjustment (e.g. FedAvg trains the full
        model locally)."""

    # ------------------------------------------------------------- cohorting
    def cohorts(self, engine, ctx: RoundContext) -> Dict[int, np.ndarray]:
        """Feasible same-depth cohorts, restricted to sampled participants."""
        out: Dict[int, np.ndarray] = {}
        for d, ids in engine.state.fleet.cohorts().items():
            ids = ids[ctx.participants[ids]]
            if len(ids):
                out[d] = ids
        return out

    # ---------------------------------------------------------- round phases
    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        raise NotImplementedError

    def cohort_step(self, engine, ctx: RoundContext, ws: Dict[str, Any],
                    d: int, ids: np.ndarray) -> CohortResult:
        raise NotImplementedError

    def fold_server(self, engine, ws: Dict[str, Any], d: int,
                    ids: np.ndarray, res: CohortResult) -> None:
        pass

    def aggregate(self, engine, ws: Dict[str, Any]) -> Tuple[Any, float]:
        """-> (new global params, round loss scalar)."""
        raise NotImplementedError

    def _finish_aggregation(self, engine, ws: Dict[str, Any],
                            server_view: Dict[str, Any],
                            agg_fn: Callable) -> Tuple[Any, float]:
        """Shared aggregation tail: filter the clients that actually trained
        (infeasible / unsampled ones contributed nothing), merge this
        round's server view into the globals, stack the client trees, and
        delegate the weighting to ``agg_fn(globals, stacked, depths,
        losses)``. Returns (new params, mean participant loss)."""
        state = engine.state
        trees, losses = ws["client_trees"], ws["losses"]
        part = [i for i, t in enumerate(trees) if t is not None]
        if not part:   # e.g. every sampled client infeasible this round
            return state.params, float("nan")
        depths = state.fleet.depths[part]
        globals_with_server = dict(state.params)
        globals_with_server.update(server_view)
        stacked = AGG.stack_client_trees(engine.cfg,
                                         [trees[i] for i in part], depths)
        new_params = agg_fn(globals_with_server, stacked, depths,
                            losses[part])
        return new_params, float(np.mean(losses[part]))

    # ------------------------------------------------------------ accounting
    def comm_cost(self, engine, d: int, available: bool) -> Tuple[int, int]:
        """-> (total bytes on the wire this round, messages) per client."""
        raise NotImplementedError


# ----------------------------------------------------------------- registry

_REGISTRY: Dict[str, Type[Strategy]] = {}


def register_strategy(name: str):
    def deco(cls: Type[Strategy]) -> Type[Strategy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> Strategy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"available: {available_strategies()}")
    return _REGISTRY[name]()


def available_strategies():
    return sorted(_REGISTRY)

"""The Strategy protocol: what a federated method must supply.

The ``Engine`` owns everything method-independent — arrival/availability
draws, client sampling, staleness tracking, batch RNG, cohorting, the
metrics ``Accountant``, history and eval. A ``Strategy`` supplies only the
method-specific pieces:

  init_round   — allocate the per-round workspace (server views, FedAvg
                 accumulators, loss buffers)
  cohort_step  — run ``local_steps`` updates for one same-depth cohort,
                 recording client trees / losses into the workspace
  fold_server  — fold a cohort's server-side result into the running
                 server view / accumulators
  aggregate    — produce the next global params + the round's loss scalar
  comm_cost    — per-client bytes and message count for the round

so the accounting that the seed trainer duplicated three times lives in
exactly one place (``Engine._account_cohort``).

Strategies register with ``@register_strategy("name")`` and are resolved by
``get_strategy(name)``; anything matching the protocol can be passed to the
engine directly, so new scenarios (unstable participation, co-tuned splits)
are a new module, not a new copy of the trainer. ``docs/strategies.md``
walks through the protocol hook by hook with ``unstable`` as the worked
example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import supernet as SN
from repro.core.fault import ArrivalProcess
from repro.optim import map_moments


@dataclasses.dataclass
class RoundContext:
    """Engine-drawn randomness + bookkeeping for one round.

    avail        — [N] bool, server reachable this round (drawn from the
                   engine's availability :class:`ArrivalProcess`)
    participants — [N] bool, client showed up: the intersection of the
                   ``sample_frac`` draw and the participation arrival
                   process (all-True when neither is configured)
    batch_fn     — ids -> stacked batch; accepts an optional ``batch_size``
                   keyword for strategies that co-tune per-client batches.
                   Legacy host path — draws from the same stream as
                   ``sample_indices``, so a strategy must use one or the
                   other, not both
    sample_indices — (ids, steps, batch_size) -> [steps, len(ids), B] int32
                   flat-dataset indices for the device-resident path: the
                   kernel gathers batches on device from
                   ``engine.device_data`` (see ``data.synthetic.DeviceData``)
    staleness    — [N] int, rounds each client has been absent since it
                   last participated (0 for a client seen last round and
                   for everyone in round 0); engine-owned, used by
                   staleness-weighted aggregation
    """
    avail: np.ndarray
    participants: np.ndarray
    batch_fn: Callable[..., Any]
    sample_indices: Callable[..., np.ndarray] = None
    staleness: np.ndarray = None


@dataclasses.dataclass
class CohortResult:
    """What ``cohort_step`` hands back for accounting + server folding."""
    client_params: int           # per-client trainable param count
    server_params: int           # server-side param count (0 => no server)
    payload: Any = None          # strategy-private, consumed by fold_server
    tokens_per_batch: int = None  # effective per-step tokens when a strategy
    #                               tunes batch sizes (None => engine default)
    losses: Any = None           # [bucket] device array, per-slot final-step
    #                               losses (never host-synced by the engine)


class Strategy:
    """Base: shared hooks with no-op defaults. Subclasses implement the
    four round phases; ``name`` is set by ``@register_strategy``."""

    name: str = "?"

    # ---------------------------------------------------- fleet construction
    def fixed_depth(self, cfg) -> int | None:
        """A rigid split point for every client, or None for Eq.1 depths."""
        return None

    def prepare_fleet(self, cfg, fleet, device_model=None) -> None:
        """Post-allocation fleet adjustment (e.g. FedAvg trains the full
        model locally; HASFL records the device model for co-tuning)."""

    def participation_process(self, cfg, n_clients: int,
                              seed: int) -> Optional[ArrivalProcess]:
        """An :class:`ArrivalProcess` governing which clients show up each
        round, or None for always-on participation. The engine prefers an
        explicitly passed ``participation=`` process over this default."""
        return None

    # ------------------------------------------------------------- cohorting
    def cohorts(self, engine, ctx: RoundContext) -> Dict[int, np.ndarray]:
        """Feasible same-depth cohorts, restricted to sampled participants."""
        out: Dict[int, np.ndarray] = {}
        for d, ids in engine.state.fleet.cohorts().items():
            ids = ids[ctx.participants[ids]]
            if len(ids):
                out[d] = ids
        return out

    # ---------------------------------------------------------- round phases
    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        raise NotImplementedError

    def cohort_step(self, engine, ctx: RoundContext, ws: Dict[str, Any],
                    d: int, ids: np.ndarray) -> CohortResult:
        raise NotImplementedError

    def fold_server(self, engine, ws: Dict[str, Any], d: int,
                    ids: np.ndarray, res: CohortResult) -> None:
        pass

    def aggregate(self, engine, ws: Dict[str, Any]) -> Tuple[Any, float]:
        """-> (new global params, round loss scalar)."""
        raise NotImplementedError

    def _finish_aggregation(self, engine, ws: Dict[str, Any],
                            server_view: Dict[str, Any],
                            agg_fn: Callable) -> Tuple[Any, float]:
        """Shared aggregation tail over the device-resident workspace:
        merge this round's server view into the globals and delegate the
        weighting to ``agg_fn(globals, stacked, depths, losses, mask)``,
        where ``stacked`` is the full-fleet ``ws["client_stack"]`` buffer
        and ``mask`` the ``ws["trained"]`` validity mask (clients that did
        not train keep zero weight; their rows are never read). This is the
        ONE host sync of the round's training outputs: the trained mask and
        per-client losses come back together, everything else stays on
        device. The participating ids land in ``ws["participated"]`` so
        host-side scenario bookkeeping can still line up per-client data.
        Returns (new params, mean participant loss)."""
        state = engine.state
        mask, losses = jax.device_get((ws["trained"], ws["losses"]))
        if not mask.any():   # e.g. every sampled client infeasible this round
            return state.params, float("nan")
        ws["participated"] = np.where(mask)[0]
        globals_with_server = dict(state.params)
        globals_with_server.update(server_view)
        new_params = agg_fn(globals_with_server, ws["client_stack"],
                            state.fleet.depths, ws["losses"], mask)
        return new_params, float(np.mean(losses[mask]))

    # ------------------------------------------------------------ accounting
    def comm_cost(self, engine, d: int, available: bool) -> Tuple[int, int]:
        """-> (total bytes on the wire this round, messages) per client."""
        raise NotImplementedError


# --------------------------------------------- device-resident fleet buffers
#
# One round's training outputs live in full-fleet stacked device buffers:
# ``client_stack`` (input-side leaves [N, ...], split-stack leaves
# [N, L_full, ...] zero-padded beyond each client's depth — exactly the
# ``core.aggregation`` stacked format), ``losses`` [N] f32 and ``trained``
# [N] bool. Cohort kernels gather their slots, train, and scatter results
# back through the helpers below; aggregation consumes the buffers directly
# with the validity mask, so nothing is sliced to host between cohorts.
# Padded slots carry the out-of-range sentinel id (``bucketing.pad_ids``):
# their scatters are dropped by jax's out-of-bounds rule, so no masking is
# needed at the buffer boundary.

def fleet_workspace(engine) -> Dict[str, Any]:
    """Fresh per-round stacked buffers for ``engine``'s fleet. With a
    fleet mesh, buffers place client-axis-sharded (the same
    ``fleet_pspecs`` layout as the stacked local heads), so the sharded
    kernels' scatters and the mask-aware aggregation reductions stay on
    their shards until the one host sync in ``_finish_aggregation``."""
    n = engine.state.n_clients
    template = SN.split_params(engine.cfg, engine.state.params,
                               engine.cfg.split_stack_len)[0]
    shapes = {"client_stack": jax.tree.map(
                  lambda x: jax.ShapeDtypeStruct((n,) + x.shape, x.dtype),
                  template),
              "losses": jax.ShapeDtypeStruct((n,), jnp.float32),
              "trained": jax.ShapeDtypeStruct((n,), jnp.bool_)}
    if engine.mesh is None:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    # build each zeros buffer directly on its client-axis shards — the
    # shape templates cost nothing, so no single-device materialize +
    # re-place round trip
    from repro.launch import sharding as SH
    shardings = SH.named(engine.mesh, SH.fleet_pspecs(shapes, engine.mesh))
    return jax.tree.map(
        lambda s, sh: jnp.zeros(s.shape, s.dtype, device=sh),
        shapes, shardings)


def scatter_rows(buf_tree, ids, rows_tree):
    """Write per-slot rows into a stacked [N, ...] buffer tree.
    ``ids`` is the sentinel-padded [bucket] id vector; padded slots drop."""
    return jax.tree.map(lambda b, r: b.at[ids].set(r.astype(b.dtype)),
                        buf_tree, rows_tree)


def gather_rows(buf_tree, ids):
    """Per-slot rows out of a stacked buffer tree; padded (sentinel) slots
    clamp to the last client's row — placeholder data their kernel slot
    trains on but never publishes."""
    return jax.tree.map(lambda b: b[ids], buf_tree)


def scatter_client_rows(cfg, ws: Dict[str, Any], ids, cstack, d: int,
                        width: float = 1.0):
    """Scatter a cohort's trained client trees (split-stack rows [:d]) into
    ``ws["client_stack"]``, zero-padding rows [d:] to the full stack depth
    (they are masked by presence at aggregation). A runtime-depth cohort
    hands back FULL-``L`` stacks whose rows [d:] were frozen at their
    broadcast (non-zero) values, so the depth window is sliced out first —
    the zero-pad invariant the aggregation denominators rely on. A
    width-sliced cohort's stack is zero-embedded back to full width
    (``supernet.widen_width``) — the pruned coordinates are excluded from
    the aggregation denominators by the per-coordinate width masks, so the
    zeros never dilute anything."""
    sname = SN.split_stack_name(cfg)
    Lfull = cfg.split_stack_len

    def pad(x):
        x = x[:, :d]   # identity for a depth-sliced stack
        return jnp.pad(x, [(0, 0), (0, Lfull - d)]
                       + [(0, 0)] * (x.ndim - 2))

    buf = ws["client_stack"]
    out = dict(buf)
    for k, v in cstack.items():
        if k == sname:
            if width < 1.0:
                v = SN.widen_width(cfg, v, width)
            rows = jax.tree.map(pad, v)
        else:
            rows = v
        out[k] = scatter_rows(buf[k], ids, rows)
    ws["client_stack"] = out


def split_param_counts(cfg, params, d: int, width: float = 1.0):
    """(client, server) parameter counts of the depth-``d`` width-``w``
    split, via ``jax.eval_shape`` — no device work. The runtime-depth
    cohort path hands full-``L`` views to the kernels, so per-cohort
    accounting can no longer just count the view's leaves."""
    c, s, _ = jax.eval_shape(lambda p: SN.split_params(cfg, p, d, width),
                             params)
    count = lambda t: sum(int(np.prod(x.shape))
                          for x in jax.tree.leaves(t))
    return count(c), count(s)


def record_cohort(ws: Dict[str, Any], ids, losses):
    """Mark a cohort's slots trained and scatter their per-slot losses
    (device arrays in, device arrays out — no host sync)."""
    ws["losses"] = ws["losses"].at[ids].set(losses.astype(jnp.float32))
    ws["trained"] = ws["trained"].at[ids].set(True)


# ----------------------------------------------- persistent server opt state
#
# The shared server branch's optimizer state lives in
# ``TrainState.opt_state["server"]``, shaped over the FULL server branch
# (the d=0 view: whole split stack + non-stack server leaves) so it is
# independent of which cohort depths exist in a given round. The
# runtime-depth kernels take the WHOLE state (``cohort_server_opt`` at
# ``d=0`` — a value-preserving full slice) and freeze moment stack rows
# ``< d`` in-kernel (``supernet.depth_freeze``), so the d=0
# ``merge_server_opt`` write-back is bit-equal to the legacy rows-``[d:]``
# slice/merge round trip. ``repro.optim.map_moments`` keeps all of this
# optimizer-agnostic.

def server_opt_state(engine, template) -> Any:
    """The persistent full-server-branch optimizer state, lazily
    initialized (and re-initialized if the stored state does not match the
    current optimizer/model — e.g. after switching optimizers between a
    save and a restore). The shape validation runs once per (engine,
    optimizer) and after every ``Engine.restore``, not on every cohort;
    adopt external state through ``Engine.restore`` so it is re-checked."""
    cur = engine.state.opt_state.get("server")
    opt_id = id(engine.optimizer)
    if cur is not None and getattr(engine, "_server_opt_ok", None) == opt_id:
        return cur
    want = jax.eval_shape(engine.optimizer.init, template)
    if cur is None or not _state_like(cur, want):
        cur = engine.optimizer.init(template)
        engine.state.opt_state["server"] = cur
    engine._server_opt_ok = opt_id
    return cur


def cohort_server_opt(engine, cfg, sname: str, d: int):
    """The cohort-step prologue every split strategy shares: fetch the
    persistent full-branch state and slice this cohort's depth-``d`` view.
    Returns ``(srv_template, srv_full, srv_state)``; after stepping, hand
    ``srv_state`` back through :func:`merge_server_opt`."""
    srv_template = SN.split_params(cfg, engine.state.params, 0)[1]
    srv_full = server_opt_state(engine, srv_template)
    return (srv_template, srv_full,
            slice_server_opt(srv_full, srv_template, sname, d))


def _state_like(state, shaped) -> bool:
    if jax.tree_util.tree_structure(state) != \
            jax.tree_util.tree_structure(shaped):
        return False
    return all(tuple(np.shape(a)) == tuple(b.shape)
               for a, b in zip(jax.tree.leaves(state),
                               jax.tree.leaves(shaped)))


def slice_server_opt(state, template, sname: str, d: int):
    """Project the depth-``d`` cohort's server slice out of the full-branch
    state: moment stack rows ``[d:]``, non-stack moments and bookkeeping
    whole. ``template`` is the full server params tree (structure probe)."""
    def sl(tree):
        out = {k: v for k, v in tree.items() if k != sname}
        out[sname] = jax.tree.map(lambda x: x[d:], tree[sname])
        return out
    return map_moments(sl, state, template)


def merge_server_opt(full, cohort, template, sname: str, d: int):
    """Write a cohort's post-update server slice back into the full-branch
    state. Stack moment rows ``[d:]`` are replaced; non-stack moments and
    bookkeeping (step counters) take the cohort's values — last cohort
    wins, mirroring the server-view fold."""
    if not isinstance(full, dict):
        return full
    pdef = jax.tree_util.tree_structure(template)
    out = {}
    for k, v in full.items():
        cv = cohort[k]
        if jax.tree_util.tree_structure(v) == pdef:
            merged = {kk: vv for kk, vv in cv.items() if kk != sname}
            merged[sname] = jax.tree.map(
                lambda f, c: jnp.concatenate([f[:d], c], axis=0),
                v[sname], cv[sname])
            out[k] = merged
        else:
            out[k] = cv
    return out


def broadcast_server_opt(state, template, n: int):
    """Stack a server opt-state slice along a new leading client axis
    (SplitFed trains per-client server copies; each starts the round from
    the shared fed-averaged moments)."""
    return map_moments(
        lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape), t),
        state, template)


def mean_server_opt(state, template, valid=None):
    """Collapse per-client server moments back to the shared state by
    averaging over the leading client axis (the moment-space analogue of
    SplitFed's round-end FedAvg over server copies). ``valid`` ([Nc] bool)
    excludes padded bucket slots from the mean — a padded slot's frozen
    broadcast copy must not dilute the live clients' moments."""
    if valid is None:
        mean = lambda x: jnp.mean(x.astype(jnp.float32), axis=0)  # fleetlint: disable=FL002 — valid=None contract: caller vouches every row is live
    else:
        nv = jnp.sum(valid).astype(jnp.float32)

        def mean(x):
            row = valid.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.sum(jnp.where(row, x.astype(jnp.float32), 0.0),
                           axis=0) / nv
    return map_moments(
        lambda t: jax.tree.map(lambda x: mean(x).astype(x.dtype), t),
        state, template)


# ----------------------------------------------------------------- registry

_REGISTRY: Dict[str, Type[Strategy]] = {}


def register_strategy(name: str):
    def deco(cls: Type[Strategy]) -> Type[Strategy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> Strategy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"available: {available_strategies()}")
    return _REGISTRY[name]()


def available_strategies():
    return sorted(_REGISTRY)

"""Unstable client participation (Wei et al.) as an engine strategy.

SuperSFL's training loop, stress-tested under an *arrival process*: clients
flap on/off following a per-client Markov (Gilbert) chain — long correlated
outages rather than i.i.d. dropouts — plus an optional per-round
deadline-straggler draw. The process itself is engine-owned
(:class:`repro.core.fault.MarkovArrivalProcess`); this strategy supplies it
through the ``participation_process`` hook and consumes the engine's
staleness ledger at aggregation time.

Staleness-weighted aggregation: a client rejoining after ``s`` missed
rounds trained this round from current globals, but its fault-tolerant
head phi_i (and therefore its reported loss) reflects an optimization
trajectory that is ``s`` rounds behind the fleet. Its Eq. 6 weight is
discounted by the standard polynomial staleness rule ``(1 + s)^-gamma``
(Xie et al., FedAsync) and the weights are renormalized to sum to 1.
``gamma=0`` recovers plain SuperSFL weighting.

This module doubles as the worked example in ``docs/strategies.md``.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.core import aggregation as AGG
from repro.core.fault import ArrivalProcess, MarkovArrivalProcess
from repro.federated.strategies.base import RoundContext, register_strategy
from repro.federated.strategies.ssfl import SuperSFL


def staleness_weights(w, staleness, gamma: float = 1.0,
                      mask=None) -> np.ndarray:
    """Discount per-client aggregation weights by ``(1 + s)^-gamma`` and
    renormalize to sum to 1. ``w`` and ``staleness`` align per client;
    ``mask`` marks the clients that trained this round (weights are 0 and
    stay 0 elsewhere — full-fleet arrays from the device-resident engine
    pass straight through)."""
    w = np.asarray(w, np.float64)
    s = np.asarray(staleness, np.float64)
    assert w.shape == s.shape
    if mask is not None:
        w = np.where(mask, w, 0.0)
    w = w * (1.0 + s) ** (-gamma)
    total = w.sum()
    if total <= 0.0:        # degenerate (all-zero Eq.6 weights): uniform
        if mask is None:
            return np.full_like(w, 1.0 / len(w))
        m = np.asarray(mask, np.float64)
        return m / m.sum()
    return w / total


@register_strategy("unstable")
class UnstableParticipation(SuperSFL):
    """SuperSFL under Markov on/off participation + staleness weighting.

    Defaults give a stationary on-fraction of 2/3 with mean outage length
    ``1/p_up ≈ 2.5`` rounds and a 10% deadline-miss rate — a harsh but
    trainable regime. Instantiate directly for other operating points::

        Engine(cfg, 16, UnstableParticipation(p_up=0.2, p_down=0.2))
    """

    def __init__(self, p_up: float = 0.4, p_down: float = 0.2,
                 straggle_p: float = 0.1, gamma: float = 1.0):
        self.p_up, self.p_down = p_up, p_down
        self.straggle_p = straggle_p
        self.gamma = gamma

    # ------------------------------------------------------- engine hooks
    def participation_process(self, cfg, n_clients: int,
                              seed: int) -> ArrivalProcess:
        return MarkovArrivalProcess(self.p_up, self.p_down,
                                    straggle_p=self.straggle_p, seed=seed)

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        ws = super().init_round(engine, ctx)
        ws["staleness"] = ctx.staleness
        return ws

    def aggregate(self, engine, ws):
        def agg_fn(globals_, stacked, depths, losses, mask):
            w = np.asarray(AGG.client_weights(depths, losses,
                                              engine.cfg.tpgf_eps,
                                              mask=mask))
            w = staleness_weights(w, ws["staleness"], self.gamma, mask=mask)
            return AGG.aggregate_weighted(engine.cfg, globals_, stacked,
                                          depths, np.asarray(w, np.float32),
                                          mask=mask)
        return self._finish_aggregation(engine, ws, ws["server_view"],
                                        agg_fn)

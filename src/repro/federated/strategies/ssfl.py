"""SuperSFL — the paper's method, as an engine strategy.

Resource-aware depths (Eq. 1), TPGF gradient fusion (Alg. 2),
fault-tolerant fallback (Alg. 3), Eq. 6/8 client-server aggregation.
ONE shared main-server model per round, updated with each cohort's pooled
gradient (Alg. 2 line 11).

Execution is device-resident and bounded-compile: ``cohort_kernel`` runs
ALL local steps for a padded cohort bucket under one ``jax.lax.scan``,
gathering batches on device from the flat dataset by index
(``data.synthetic.DeviceData``), so one compiled program per
(width, bucket, batch size, steps) covers every cohort shape the fleet can
produce — depth is a RUNTIME argument (masked scan over the full layer
stack, see ``model.run_stack``), so per-round depth re-tuning never
recompiles. Padded slots are masked out of the pooled server gradient,
carry ``avail=False`` (they can never unfreeze the server), and their
outputs are dropped at the sentinel-id scatter (see
``federated.bucketing``).

Optimizer state is split the same way the parameters are: the client /
local-head groups are re-initialized per cohort (clients re-download their
subnetwork every round, so momentum has nothing to carry), while the shared
server branch's moments persist across rounds in
``TrainState.opt_state["server"]`` and stream through cohorts in cohort
order — the moment-space mirror of Alg. 2's pooled sequential server
update. See ``strategies.base.server_opt_state``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.federated import bucketing as BK
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.launch.sharding import P, slot_pspec
from repro.optim import apply_updates


def _cohort_specs(axes, d, client_stack, local_stack, server_p,
                  images, labels, idx, avail, valid, srv_state):
    """shard_map layout: slot-leading stacks and masks shard over the
    fleet axes, the runtime depth scalar, the shared server tree / moments
    and the flat dataset replicate; outputs mirror the inputs (per-slot
    losses stay sharded)."""
    slot = slot_pspec(0, axes)
    in_specs = (P(), slot, slot, P(), P(), P(), slot_pspec(1, axes),
                slot, slot, P())
    out_specs = (slot, slot, P(), P(), slot, slot)
    return in_specs, out_specs


@BK.register_kernel(n_static=4, specs=_cohort_specs)
def cohort_kernel(cfg: ModelConfig, opt, steps: int, width: float, d,
                  client_stack, local_stack, server_p,
                  images, labels, idx, avail, valid, srv_state,
                  axis_name=None):
    """All ``steps`` TPGF local steps for one padded cohort bucket of
    runtime depth ``d`` and width tier ``width``, as one compiled scan.

    ``d`` is a RUNTIME jax scalar, not a static key: client_stack and
    server_p both hold all ``L`` split-stack rows, the masked scans in
    ``model.run_stack`` apply only the in-window layers (prefix rows
    ``< d`` client-side, suffix rows ``>= d`` server-side, bit-exact vs
    the static slice), and ``supernet.depth_freeze`` reverts every
    optimizer touch of an out-of-window row — so ONE compiled program per
    (width, bucket, batch shape) serves every depth tier the fleet can
    produce. Client moment rows ``>= d`` stay exactly zero on their own
    (zero grads into zero-initialized ephemeral moments); the param rows
    still freeze because decoupled weight decay would move them.

    client_stack/local_stack: [Nc, ...] stacked client/local param trees
    (Nc = bucket size, or bucket/shards under shard_map); at ``width < 1``
    the client stack is the ``supernet.slice_width`` view (full-``L``
    rows, sliced channels) so the pruned coordinates are never
    materialized. server_p: shared server tree (always full-width — the
    smashed data is full ``d_model``). images/labels: the flat
    device-resident dataset; idx: [steps, Nc, B] flat sample indices
    (batches are gathered on device each step). avail: [Nc] bool, server
    reachable (False on padded slots). valid: [Nc] bool, real-client
    slots. ``opt`` is a ``repro.optim.Optimizer``; the ephemeral
    client/local state is initialized inside the kernel, ``srv_state`` is
    the cross-round FULL shared server branch state and threads through
    the scan (rows ``< d`` ride along frozen). ``axis_name`` is the fleet
    mesh axes when the kernel runs shard-mapped (cross-slot reductions
    then span every shard; see ``federated.bucketing``). ``width`` is
    STATIC — the compile key is (width, bucket).
    """

    wcfg = SN.width_cfg(cfg, width)

    # a padded slot can never unfreeze the server; avail is already forced
    # False there, but guard with valid too so the invariant cannot depend
    # on the caller's padding discipline
    anyav = BK.freeze_gate(avail, valid, axis_name)

    def step(carry, idx_t):
        cstack, lstack, srv_p, eph_state, s_state = carry
        BK.guard_gather(idx_t, images.shape[0])   # sanitize-mode OOB check
        batch = {"images": images[idx_t], "label": labels[idx_t]}

        def one(cp, lp, b, av):
            # closes over the CARRY's server params: each local step sees
            # the pooled server update of the previous step (Alg. 2)
            out = T.tpgf_grads_split(cfg, wcfg, cp, srv_p, lp, b, d,
                                     server_available=av)
            return (out.g_client, out.g_server, out.g_local,
                    out.loss_client, out.loss_server)

        gc, gs, gl, l_c, l_s = jax.vmap(one, in_axes=(0, 0, 0, 0))(
            cstack, lstack, batch, avail)
        # SuperSFL (Alg. 2 line 11): ONE shared main-server model, updated
        # with the cohort's pooled gradient as the smashed batches stream
        # in. Padded slots contribute zero to the pool and are excluded
        # from the denominator; under shard_map the mean spans every shard.
        gs_mean = BK.masked_slot_mean(gs, valid, axis_name)
        eph_groups = {"client": cstack, "local": lstack}
        eph_updates, eph_state = opt.update({"client": gc, "local": gl},
                                            eph_state, eph_groups)
        srv_updates, new_s_state = opt.update(gs_mean, s_state, srv_p)
        new = apply_updates(eph_groups, eph_updates)
        new_server = apply_updates(srv_p, srv_updates)
        # runtime-depth row freeze: out-of-window stack rows must be a
        # bit-exact no-op so the host's d=0 opt-state round trip and the
        # aggregation's zero-pad contract both hold
        new_client = SN.depth_freeze(cfg, new["client"], cstack, d,
                                     keep="prefix", axis=1)
        new_server = SN.depth_freeze(cfg, new_server, srv_p, d,
                                     keep="suffix")
        new_s_state = SN.depth_freeze(cfg, new_s_state, s_state, d,
                                      keep="suffix")
        # fault-tolerance invariant (tpgf "frozen server"): a cohort that
        # never reached the server must be a bit-exact server no-op —
        # carried moments would otherwise still step the params (momentum
        # decay) and advance
        freeze = lambda n_, o: jax.tree.map(
            lambda a, b_: jnp.where(anyav, a, b_), n_, o)
        new_server = freeze(new_server, srv_p)
        s_state = freeze(new_s_state, s_state)
        return ((new_client, new["local"], new_server, eph_state,
                 s_state), (l_c, l_s))

    eph_state = opt.init({"client": client_stack, "local": local_stack})
    carry = (client_stack, local_stack, server_p, eph_state, srv_state)
    (cstack, lstack, server_p, _, srv_state), (l_c, l_s) = jax.lax.scan(
        step, carry, idx)
    return cstack, lstack, server_p, srv_state, l_c[-1], l_s[-1]


@register_strategy("ssfl")
class SuperSFL(Strategy):

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        sname = SN.split_stack_name(engine.cfg)
        params = engine.state.params
        ws = base.fleet_workspace(engine)
        # running server view: full-L split stack + non-stack server leaves
        ws["server_view"] = {sname: jax.tree.map(lambda x: x,
                                                 params[sname])}
        return ws

    @staticmethod
    def _width_groups(engine, ids):
        """Order-preserving same-width sub-cohorts: jit kernels need one
        static width per call, so a width-heterogeneous cohort becomes
        several kernel launches chained through the shared server branch
        (exactly how hasfl chains same-batch groups). A homogeneous
        full-width fleet yields the single group ``[(1.0, ids)]`` — the
        legacy call sequence, bit-exact."""
        widths = getattr(engine.state.fleet, "widths", None)
        ids = np.asarray(ids)
        if widths is None:
            return [(1.0, ids)]
        groups: Dict[float, list] = {}
        for i in ids:
            groups.setdefault(float(widths[i]), []).append(int(i))
        return [(w, np.asarray(g)) for w, g in sorted(groups.items())]

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        # runtime depth: full-L views into the one compiled kernel per
        # (width, bucket); the kernel masks/freezes rows by the traced d
        client_p, server_p, _ = SN.split_params(cfg, state.params, None)
        # the shared server branch's moments persist across rounds: hand
        # the kernel the WHOLE state (d=0 slice = full copy) — it freezes
        # moment rows < d in-kernel, so the d=0 merge below is bit-equal
        # to the legacy depth-sliced round trip
        srv_template, srv_full, srv_state = base.cohort_server_opt(
            engine, cfg, sname, 0)
        losses = None
        csum = 0
        groups = self._width_groups(engine, ids)
        fused = len(groups) > 1 and engine.cross_tier == "fused"
        tiers, tier_states, live = [], [], []
        base_server, base_state = server_p, srv_state
        for w, gids in groups:
            group_p = client_p if w >= 1.0 else \
                SN.split_params(cfg, state.params, None, w)[0]
            # fused: every tier starts from the SAME server snapshot;
            # chained (legacy / comparator): from the previous tier's
            src = (base_server, base_state) if fused \
                else (server_p, srv_state)
            server_p, srv_state, losses, mass = self._run_subcohort(
                engine, ctx, ws, d, gids, group_p, src[0], src[1],
                width=w)
            if fused:
                tiers.append(T.TierUpdate(1.0, mass, server_p))
                tier_states.append(srv_state)
                live.append(bool(ctx.avail[gids].any()))
            csum += len(gids) * base.split_param_counts(
                cfg, state.params, d, w)[0]
        if fused:
            # ONE cross-tier TPGF update: the server branch is full-width
            # (the smashed data is full d_model), so each tier enters at
            # width 1.0 with its Eq. 6-style mass — summed inverse fused
            # losses of its live clients — and delta-mode fuse_tiers
            # keeps an all-frozen cohort a bit-exact server no-op
            server_p = T.fuse_tiers(cfg, tiers, base=base_server,
                                    use_pallas=cfg.use_pallas)
            srv_state = self._fuse_server_state(
                cfg, base_state, tier_states,
                [t.weight for t in tiers], live, base_server)
        state.opt_state["server"] = base.merge_server_opt(
            srv_full, srv_state, srv_template, sname, 0)
        cparams = csum // max(len(ids), 1)
        sparams = base.split_param_counts(cfg, state.params, d)[1]
        return CohortResult(cparams, sparams, payload=server_p,
                            losses=losses)

    @staticmethod
    def _fuse_server_state(cfg, base_state, tier_states, masses, live,
                           server_tpl):
        """Cross-tier fusion of the shared server optimizer state.

        Moment entries (dicts mirroring the server branch tree, the
        ``optim.map_moments`` criterion) fuse in delta mode with the same
        tier masses as the parameters, so moments and params move under
        one law. Bookkeeping entries (AdamW's ``t``) are not averageable:
        every live tier stepped the same count from the same base, so the
        first live tier's value is taken — and the base's when the whole
        cohort was frozen, keeping the no-op bit-exact. ``live`` comes
        from the host-side availability draw (no device sync)."""
        if not isinstance(base_state, dict):
            return base_state                      # stateless (sgd)
        pdef = jax.tree_util.tree_structure(server_tpl)
        first_live = next((i for i, lv in enumerate(live) if lv), None)
        out = {}
        for k, bv in base_state.items():
            if jax.tree_util.tree_structure(bv) == pdef:
                out[k] = T.fuse_tiers(
                    cfg, [T.TierUpdate(1.0, m, ts[k])
                          for m, ts in zip(masses, tier_states)], base=bv)
            else:
                out[k] = bv if first_live is None \
                    else tier_states[first_live][k]
        return out

    def _run_subcohort(self, engine, ctx, ws, d, ids, client_p, server_p,
                       srv_state, batch_size: int = None,
                       width: float = 1.0):
        """All local steps for ``ids`` in ONE bucketed kernel call:
        ephemeral client/local optimizer state, threaded server params +
        moments, on-device batch gather. ``client_p`` must already be the
        width-``width`` slice when ``width < 1``. Returns the updated
        ``(server_p, srv_state, losses, mass)`` so callers can chain
        sub-cohorts (HASFL's same-depth batch groups, width tiers)
        through the shared branch — ``mass`` is the group's Eq. 6-style
        tier weight for cross-tier fusion: summed inverse fused losses
        over the slots that actually reached the server (an all-frozen
        group has mass exactly 0, so ``fuse_tiers`` no-ops it)."""
        cfg, state = engine.cfg, engine.state
        bs = engine.batch_size if batch_size is None else batch_size
        n = state.n_clients
        bucket = engine.bucket_for(len(ids))
        pids = jnp.asarray(BK.pad_ids(np.asarray(ids), bucket, n))
        valid = jnp.asarray(np.arange(bucket) < len(ids))
        avail = jnp.asarray(BK.pad_rows(
            np.asarray(ctx.avail[ids], bool), bucket, fill=False))
        idx = jnp.asarray(BK.pad_slot_axis(
            ctx.sample_indices(ids, engine.local_steps, bs), bucket, axis=1))
        cstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (bucket,) + x.shape), client_p)
        lstack = base.gather_rows(state.local_heads, pids)
        dd = engine.device_data
        kernel = engine.kernel_fn(cohort_kernel, bucket)
        cstack, lstack, server_p, srv_state, l_c, l_s = kernel(
            cfg, engine.optimizer, engine.local_steps, width,
            jnp.int32(d), cstack, lstack, server_p, dd.images, dd.labels,
            idx, avail, valid, srv_state)
        # publish: heads + client trees scatter back (padded slots drop at
        # the sentinel ids), per-slot losses stay on device
        state.local_heads = base.scatter_rows(state.local_heads, pids,
                                              lstack)
        base.scatter_client_rows(cfg, ws, pids, cstack, d, width)
        losses = jnp.where(
            avail,
            T.fused_loss(l_c, l_s, d, cfg.split_stack_len - d,
                         cfg.tpgf_eps, cfg.tpgf_variant),
            l_c)
        base.record_cohort(ws, pids, losses)
        # Eq. 6-style tier mass for cross-tier fusion: inverse fused loss,
        # where-guarded over the slots that reached the server (padded and
        # unreachable slots contribute exactly 0 — FL002 contract)
        mass = jnp.sum(jnp.where(valid & avail,
                                 1.0 / (losses + cfg.tpgf_eps), 0.0))
        return server_p, srv_state, losses, mass

    def fold_server(self, engine, ws, d, ids, res) -> None:
        # the cohort's payload stack is full-L (runtime-depth kernel);
        # rows < d rode along frozen, so only the trained suffix folds in
        sname = SN.split_stack_name(engine.cfg)
        server_p, sv = res.payload, ws["server_view"]
        sv[sname] = jax.tree.map(
            lambda full, nd: jnp.concatenate([full[:d], nd[d:]], axis=0),
            sv[sname], server_p[sname])
        for k, v in server_p.items():
            if k != sname:
                sv[k] = v

    def aggregate(self, engine, ws):
        # Eq. 6 weights (depth x inverse fused loss) + Eq. 8 averaging;
        # per-coordinate width denominators kick in only when some client
        # trained a width-sliced tier (homogeneous fleets: legacy path)
        widths = getattr(engine.state.fleet, "widths", None)
        return self._finish_aggregation(
            engine, ws, ws["server_view"],
            lambda g, s, dep, l, m: AGG.aggregate(engine.cfg, g, s, dep, l,
                                                  mask=m, widths=widths)[0])

    def comm_cost(self, engine, d, available, ids=None):
        # only the client subnetwork crosses the network (paper §III-C);
        # ssfl fallback mode skips the smashed-activation traffic. The
        # smashed data is full d_model at every width tier, so only the
        # parameter download scales with width.
        per_step = 2 * engine.smashed_bytes(d) if available else 0
        msgs = 2 + 2 * engine.local_steps
        widths = getattr(engine.state.fleet, "widths", None)
        hetero = widths is not None and bool(
            (np.asarray(widths) < 1.0).any())
        if ids is not None and hetero:
            by_tier: Dict[float, int] = {}
            pbytes = np.array(
                [by_tier.setdefault(
                    float(widths[i]),
                    SN.client_param_bytes(engine.cfg, engine.state.params,
                                          d, float(widths[i])))
                 for i in np.asarray(ids)], np.int64)
            return (2 * pbytes + engine.local_steps * per_step,
                    np.full(len(pbytes), msgs, np.int64))
        pbytes = SN.client_param_bytes(engine.cfg, engine.state.params, d)
        return 2 * pbytes + engine.local_steps * per_step, msgs

"""SuperSFL — the paper's method, as an engine strategy.

Resource-aware depths (Eq. 1), TPGF gradient fusion (Alg. 2),
fault-tolerant fallback (Alg. 3), Eq. 6/8 client-server aggregation.
ONE shared main-server model per round, updated with each cohort's pooled
gradient (Alg. 2 line 11).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.optim import apply_updates


@functools.partial(jax.jit, static_argnames=("cfg", "d", "opt"))
def cohort_kernel(cfg: ModelConfig, d: int, opt,
                  client_stack, local_stack, server_p, batch_stack, avail,
                  opt_state):
    """One TPGF step for a cohort of clients sharing depth ``d``.

    client_stack/local_stack: [Nc, ...] stacked client/local param trees.
    server_p: shared server tree. avail: [Nc] bool. ``opt`` is a
    ``repro.optim.Optimizer`` applied jointly to all three groups.
    """

    def one(cp, lp, b, av):
        full = SN.merge_params(cfg, cp, server_p, lp)
        out = T.tpgf_grads(cfg, full, b, d, server_available=av)
        gc, gs, gl = SN.split_params(cfg, out.grads, d)
        return gc, gs, gl, out.loss_client, out.loss_server

    gc, gs, gl, l_c, l_s = jax.vmap(one, in_axes=(0, 0, 0, 0))(
        client_stack, local_stack, batch_stack, avail)
    # SuperSFL (Alg. 2 line 11): ONE shared main-server model, updated with
    # the cohort's pooled gradient as the smashed batches stream in.
    gs_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)
    groups = {"client": client_stack, "local": local_stack,
              "server": server_p}
    grads = {"client": gc, "local": gl, "server": gs_mean}
    updates, opt_state = opt.update(grads, opt_state, groups)
    new = apply_updates(groups, updates)
    return (new["client"], new["local"], new["server"], opt_state,
            l_c, l_s)


@register_strategy("ssfl")
class SuperSFL(Strategy):

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        sname = SN.split_stack_name(engine.cfg)
        params = engine.state.params
        # running server view: full-L split stack + non-stack server leaves
        return {"client_trees": [None] * engine.state.n_clients,
                "losses": np.zeros(engine.state.n_clients),
                "server_view": {sname: jax.tree.map(lambda x: x,
                                                    params[sname])}}

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        cfg, state = engine.cfg, engine.state
        client_p, server_p, _ = SN.split_params(cfg, state.params, d)
        cstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), client_p)
        lstack = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[state.local_heads[i] for i in ids])
        av = jnp.asarray(ctx.avail[ids])
        opt_state = engine.optimizer.init(
            {"client": cstack, "local": lstack, "server": server_p})
        l_c = l_s = None
        for _ in range(engine.local_steps):
            bstack = ctx.batch_fn(ids)
            cstack, lstack, server_p, opt_state, l_c, l_s = cohort_kernel(
                cfg, d, engine.optimizer, cstack, lstack, server_p, bstack,
                av, opt_state)
        # persist local heads + collect client trees for aggregation
        for j, i in enumerate(ids):
            state.local_heads[i] = jax.tree.map(lambda x: x[j], lstack)
            ws["client_trees"][i] = jax.tree.map(lambda x: x[j], cstack)
            lc, ls = float(l_c[j]), float(l_s[j])
            if ctx.avail[i]:
                ws["losses"][i] = float(T.fused_loss(
                    lc, ls, d, cfg.split_stack_len - d, cfg.tpgf_eps))
            else:
                ws["losses"][i] = lc
        cparams = sum(int(x.size) for x in jax.tree.leaves(client_p))
        sparams = sum(int(x.size) for x in jax.tree.leaves(server_p))
        return CohortResult(cparams, sparams, payload=server_p)

    def fold_server(self, engine, ws, d, ids, res) -> None:
        sname = SN.split_stack_name(engine.cfg)
        server_p, sv = res.payload, ws["server_view"]
        sv[sname] = jax.tree.map(
            lambda full, nd: jnp.concatenate([full[:d], nd], axis=0),
            sv[sname], server_p[sname])
        for k, v in server_p.items():
            if k != sname:
                sv[k] = v

    def aggregate(self, engine, ws):
        # Eq. 6 weights (depth x inverse fused loss) + Eq. 8 averaging
        return self._finish_aggregation(
            engine, ws, ws["server_view"],
            lambda g, s, d, l: AGG.aggregate(engine.cfg, g, s, d, l)[0])

    def comm_cost(self, engine, d, available):
        # only the client subnetwork crosses the network (paper §III-C);
        # ssfl fallback mode skips the smashed-activation traffic
        pbytes = SN.client_param_bytes(engine.cfg, engine.state.params, d)
        per_step = 2 * engine.smashed_bytes(d) if available else 0
        return (2 * pbytes + engine.local_steps * per_step,
                2 + 2 * engine.local_steps)

"""SuperSFL — the paper's method, as an engine strategy.

Resource-aware depths (Eq. 1), TPGF gradient fusion (Alg. 2),
fault-tolerant fallback (Alg. 3), Eq. 6/8 client-server aggregation.
ONE shared main-server model per round, updated with each cohort's pooled
gradient (Alg. 2 line 11).

Optimizer state is split the same way the parameters are: the client /
local-head groups are re-initialized per cohort (clients re-download their
subnetwork every round, so momentum has nothing to carry), while the shared
server branch's moments persist across rounds in
``TrainState.opt_state["server"]`` and stream through cohorts in cohort
order — the moment-space mirror of Alg. 2's pooled sequential server
update. See ``strategies.base.server_opt_state``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.optim import apply_updates


@functools.partial(jax.jit, static_argnames=("cfg", "d", "opt"))
def cohort_kernel(cfg: ModelConfig, d: int, opt,
                  client_stack, local_stack, server_p, batch_stack, avail,
                  eph_state, srv_state):
    """One TPGF step for a cohort of clients sharing depth ``d``.

    client_stack/local_stack: [Nc, ...] stacked client/local param trees.
    server_p: shared server tree. avail: [Nc] bool. ``opt`` is a
    ``repro.optim.Optimizer``; ``eph_state`` covers the per-round client +
    local groups, ``srv_state`` the cross-round shared server branch.
    """

    def one(cp, lp, b, av):
        full = SN.merge_params(cfg, cp, server_p, lp)
        out = T.tpgf_grads(cfg, full, b, d, server_available=av)
        gc, gs, gl = SN.split_params(cfg, out.grads, d)
        return gc, gs, gl, out.loss_client, out.loss_server

    gc, gs, gl, l_c, l_s = jax.vmap(one, in_axes=(0, 0, 0, 0))(
        client_stack, local_stack, batch_stack, avail)
    # SuperSFL (Alg. 2 line 11): ONE shared main-server model, updated with
    # the cohort's pooled gradient as the smashed batches stream in.
    gs_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)
    eph_groups = {"client": client_stack, "local": local_stack}
    eph_updates, eph_state = opt.update({"client": gc, "local": gl},
                                        eph_state, eph_groups)
    srv_updates, new_srv_state = opt.update(gs_mean, srv_state, server_p)
    new = apply_updates(eph_groups, eph_updates)
    new_server = apply_updates(server_p, srv_updates)
    # fault-tolerance invariant (tpgf "frozen server"): a cohort that never
    # reached the server must be a bit-exact server no-op — carried moments
    # would otherwise still step the params (momentum decay) and advance
    anyav = jnp.any(avail)
    freeze = lambda n, o: jax.tree.map(
        lambda a, b: jnp.where(anyav, a, b), n, o)
    new_server = freeze(new_server, server_p)
    srv_state = freeze(new_srv_state, srv_state)
    return (new["client"], new["local"], new_server, eph_state, srv_state,
            l_c, l_s)


@register_strategy("ssfl")
class SuperSFL(Strategy):

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        sname = SN.split_stack_name(engine.cfg)
        params = engine.state.params
        # running server view: full-L split stack + non-stack server leaves
        return {"client_trees": [None] * engine.state.n_clients,
                "losses": np.zeros(engine.state.n_clients),
                "server_view": {sname: jax.tree.map(lambda x: x,
                                                    params[sname])}}

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        client_p, server_p, _ = SN.split_params(cfg, state.params, d)
        # the shared server branch's moments persist across rounds: slice
        # this cohort's depth-d rows out, step, and fold them back below
        srv_template, srv_full, srv_state = base.cohort_server_opt(
            engine, cfg, sname, d)
        server_p, srv_state = self._run_subcohort(
            engine, ctx, ws, d, ids, client_p, server_p, srv_state)
        state.opt_state["server"] = base.merge_server_opt(
            srv_full, srv_state, srv_template, sname, d)
        cparams = sum(int(x.size) for x in jax.tree.leaves(client_p))
        sparams = sum(int(x.size) for x in jax.tree.leaves(server_p))
        return CohortResult(cparams, sparams, payload=server_p)

    def _run_subcohort(self, engine, ctx, ws, d, ids, client_p, server_p,
                       srv_state, batch_size: int = None):
        """Local steps for ``ids`` (one jit shape): ephemeral client/local
        optimizer state, threaded server params + moments. Returns the
        updated ``(server_p, srv_state)`` so callers can chain sub-cohorts
        (HASFL's same-depth batch groups) through the shared branch."""
        cfg, state = engine.cfg, engine.state
        cstack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), client_p)
        lstack = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[state.local_heads[i] for i in ids])
        av = jnp.asarray(ctx.avail[ids])
        eph_state = engine.optimizer.init({"client": cstack, "local": lstack})
        l_c = l_s = None
        for _ in range(engine.local_steps):
            bstack = ctx.batch_fn(ids, batch_size=batch_size)
            (cstack, lstack, server_p, eph_state, srv_state, l_c, l_s) = \
                cohort_kernel(cfg, d, engine.optimizer, cstack, lstack,
                              server_p, bstack, av, eph_state, srv_state)
        # persist local heads + collect client trees for aggregation
        for j, i in enumerate(ids):
            state.local_heads[i] = jax.tree.map(lambda x: x[j], lstack)
            ws["client_trees"][i] = jax.tree.map(lambda x: x[j], cstack)
            lc, ls = float(l_c[j]), float(l_s[j])
            if ctx.avail[i]:
                ws["losses"][i] = float(T.fused_loss(
                    lc, ls, d, cfg.split_stack_len - d, cfg.tpgf_eps))
            else:
                ws["losses"][i] = lc
        return server_p, srv_state

    def fold_server(self, engine, ws, d, ids, res) -> None:
        sname = SN.split_stack_name(engine.cfg)
        server_p, sv = res.payload, ws["server_view"]
        sv[sname] = jax.tree.map(
            lambda full, nd: jnp.concatenate([full[:d], nd], axis=0),
            sv[sname], server_p[sname])
        for k, v in server_p.items():
            if k != sname:
                sv[k] = v

    def aggregate(self, engine, ws):
        # Eq. 6 weights (depth x inverse fused loss) + Eq. 8 averaging
        return self._finish_aggregation(
            engine, ws, ws["server_view"],
            lambda g, s, d, l: AGG.aggregate(engine.cfg, g, s, d, l)[0])

    def comm_cost(self, engine, d, available):
        # only the client subnetwork crosses the network (paper §III-C);
        # ssfl fallback mode skips the smashed-activation traffic
        pbytes = SN.client_param_bytes(engine.cfg, engine.state.params, d)
        per_step = 2 * engine.smashed_bytes(d) if available else 0
        return (2 * pbytes + engine.local_steps * per_step,
                2 + 2 * engine.local_steps)

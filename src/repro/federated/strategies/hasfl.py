"""HASFL-style heterogeneity-aware batch/split co-tuning (Lin et al.).

SuperSFL's training loop, with the fleet re-tuned EVERY round: instead of
fixing each client at its Eq. 1 memory-capacity depth with one global batch
size, the strategy jointly picks a (split depth, batch size) pair per
client from the device model's compute/communication cost estimates
(``repro.core.allocation.co_tune``), so fast devices grow their batches
while stragglers shed depth/batch instead of stalling the synchronous
round barrier.

The solver runs in ``init_round`` — the per-round analogue of
``prepare_fleet`` (it needs the live parameter tree for per-depth parameter
counts, which the construction-time hook does not see). Depths are written
back into ``fleet.depths`` (never above ``fleet.capacity``, so every
assignment stays feasible), and ``cohort_step`` splits each same-depth
cohort into same-batch sub-cohorts: jit kernels need one batch shape per
call, so heterogeneity *within* a cohort becomes several kernel launches
chained through the shared server branch — each group continues from the
previous group's server params and optimizer moments (Alg. 2 line 11's
pooled sequential update, at sub-cohort granularity).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocation as AL
from repro.core import supernet as SN
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             register_strategy)
from repro.federated.strategies.ssfl import SuperSFL


@register_strategy("hasfl")
class HASFL(SuperSFL):
    """Per-round joint depth/batch co-tuning on the SuperSFL round."""

    def __init__(self, batch_choices=(4, 8, 16, 32),
                 time_budget_factor: float = 1.0, width_tiers=None):
        self.batch_choices = tuple(batch_choices)
        self.time_budget_factor = time_budget_factor
        # optional supernet width ladder, e.g. (0.5, 0.75, 1.0): co_tune
        # then emits a per-client width tier beside (depth, batch) and the
        # tiers land in fleet.widths. None keeps the depth/batch-only
        # solve (and the legacy goldens) untouched.
        self.width_tiers = None if width_tiers is None \
            else tuple(sorted(width_tiers))
        self._dm = None
        self._bs: np.ndarray = None        # [N] per-client batch size

    # ------------------------------------------------------- fleet tuning
    def prepare_fleet(self, cfg, fleet, device_model=None) -> None:
        """Record the device model; the actual (depth, batch) solve runs in
        ``init_round`` each round, where the parameter tree is available."""
        self._dm = device_model

    def retune(self, engine) -> None:
        """Re-solve every client's (split depth, batch size) from the cost
        model. Idempotent while profiles are static; profile drift or a
        changed device model is picked up the next round."""
        cfg, fleet = engine.cfg, engine.state.fleet
        dm = self._dm or engine.accountant.dm
        params = engine.state.params
        sname = SN.split_stack_name(cfg)
        per_layer = sum(int(x.size) // x.shape[0]
                        for x in jax.tree.leaves(params[sname]))
        input_side = sum(int(x.size) for x in jax.tree.leaves(
            SN.split_params(cfg, params, 0)[0]))
        counts = np.array([input_side + d * per_layer
                           for d in range(cfg.split_stack_len + 1)])
        tps = engine.tokens_per_sample()
        tuned = AL.co_tune(
            fleet.capacity,
            [p.mem_gb for p in fleet.profiles],
            [p.lat_ms for p in fleet.profiles],
            counts, tps, tps * cfg.d_model * 4,
            batch_choices=self.batch_choices,
            base_batch=engine.batch_size,
            time_budget_factor=self.time_budget_factor,
            gflops_per_mem=dm.client_gflops_per_mem,
            bandwidth_mb_s=dm.bandwidth_mb_s,
            width_tiers=self.width_tiers)
        if self.width_tiers is not None:
            depths, self._bs, fleet.widths = tuned
        else:
            depths, self._bs = tuned
        fleet.depths = depths
        fleet.feasible = fleet.depths <= fleet.capacity

    # ------------------------------------------------------- round phases
    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        self.retune(engine)
        return super().init_round(engine, ctx)

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        """Split the depth-``d`` cohort into same-batch sub-cohorts (jit
        kernels need one batch shape per call) and CHAIN them through the
        shared server branch: each group starts from the previous group's
        server params and moments, so no sub-cohort's server compute is
        overwritten. The engine folds the final result once. Each sub-group
        is itself bucketed and depth rides the kernel as a RUNTIME scalar,
        so the compile key is (width, bucket, batch choice) — independent
        of how re-tuning reshuffles the fleet's depths — and
        under ``Engine(mesh=...)`` each group rides the shared ssfl
        kernel's shard_map variant (sub-group buckets round up to whole
        slots per shard like any other cohort)."""
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        # runtime depth: full-L views + full opt state (d=0), exactly as
        # in SuperSFL.cohort_step — re-tuned depths reuse the same
        # compiled (width, bucket, batch) kernels
        client_p, server_p, _ = SN.split_params(cfg, state.params, None)
        srv_template, srv_full, srv_state = base.cohort_server_opt(
            engine, cfg, sname, 0)
        widths = getattr(state.fleet, "widths", None)
        groups: Dict[tuple, list] = {}
        for i in np.asarray(ids):
            w = 1.0 if widths is None else float(widths[i])
            groups.setdefault((int(self._bs[i]), w), []).append(int(i))
        wkeys = sorted({w for _, w in groups})
        if len(wkeys) > 1 and engine.cross_tier == "fused":
            # cross-tier TPGF at width-tier granularity: batch groups
            # WITHIN a tier still chain (same slice, Alg. 2's sequential
            # pooled update), but every tier starts from the same server
            # snapshot and the per-tier results fuse into ONE update —
            # the tier mass is the sum of its batch groups' masses
            from repro.core import tpgf as T
            base_server, base_state = server_p, srv_state
            tiers, tier_states, live = [], [], []
            for w in wkeys:
                group_p = client_p if w >= 1.0 else \
                    SN.split_params(cfg, state.params, None, w)[0]
                t_server, t_state = base_server, base_state
                mass, any_live = jnp.float32(0.0), False
                for (b, w2), gids in sorted(groups.items()):
                    if w2 != w:
                        continue
                    t_server, t_state, _, m = self._run_subcohort(
                        engine, ctx, ws, d, np.asarray(gids), group_p,
                        t_server, t_state, batch_size=b, width=w)
                    mass = mass + m
                    any_live = any_live or bool(ctx.avail[gids].any())
                tiers.append(T.TierUpdate(1.0, mass, t_server))
                tier_states.append(t_state)
                live.append(any_live)
            server_p = T.fuse_tiers(cfg, tiers, base=base_server,
                                    use_pallas=cfg.use_pallas)
            srv_state = self._fuse_server_state(
                cfg, base_state, tier_states,
                [t.weight for t in tiers], live, base_server)
        else:
            for (b, w), gids in sorted(groups.items()):
                group_p = client_p if w >= 1.0 else \
                    SN.split_params(cfg, state.params, None, w)[0]
                server_p, srv_state, _, _ = self._run_subcohort(
                    engine, ctx, ws, d, np.asarray(gids), group_p,
                    server_p, srv_state, batch_size=b, width=w)
        state.opt_state["server"] = base.merge_server_opt(
            srv_full, srv_state, srv_template, sname, 0)
        cparams, sparams = base.split_param_counts(cfg, state.params, d)
        mean_b = float(np.mean([self._bs[i] for i in np.asarray(ids)]))
        return CohortResult(cparams, sparams, payload=server_p,
                            tokens_per_batch=int(
                                mean_b * engine.tokens_per_sample()))

    # -------------------------------------------------------- accounting
    def comm_cost(self, engine, d, available, ids=None):
        """ssfl's cost with the smashed traffic scaled to each client's
        *tuned* batch size: with ``ids`` the engine gets exact per-client
        pricing (arrays aligned with ``ids``); without, the fleet-wide mean
        for this depth keeps legacy callers working."""
        pbytes = SN.client_param_bytes(engine.cfg, engine.state.params, d)
        per_tok = (engine.tokens_per_sample() * engine.cfg.d_model
                   * jnp.dtype(engine.cfg.dtype).itemsize)
        msgs = 2 + 2 * engine.local_steps
        if ids is not None and self._bs is not None:
            bs = self._bs[np.asarray(ids)].astype(np.float64)
            per_step = 2 * (bs * per_tok).astype(np.int64) if available \
                else np.zeros(len(bs), np.int64)
            widths = getattr(engine.state.fleet, "widths", None)
            if widths is not None and bool((np.asarray(widths) < 1.0).any()):
                # width-tiered download: each client ships only its slice
                by_tier: Dict[float, int] = {}
                pbytes = np.array(
                    [by_tier.setdefault(
                        float(widths[i]),
                        SN.client_param_bytes(engine.cfg,
                                              engine.state.params, d,
                                              float(widths[i])))
                     for i in np.asarray(ids)], np.int64)
            return (2 * pbytes + engine.local_steps * per_step,
                    np.full(len(bs), msgs, np.int64))
        mean_b = None
        if self._bs is not None:
            mask = engine.state.fleet.depths == d
            if mask.any():
                mean_b = float(self._bs[mask].mean())
        if mean_b is None:   # before the first round: engine default
            mean_b = float(engine.batch_size)
        per_step = 2 * int(mean_b * per_tok) if available else 0
        return 2 * pbytes + engine.local_steps * per_step, msgs

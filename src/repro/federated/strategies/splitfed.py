"""SplitFed baselines (SFL + dynamic-split DFL) as engine strategies.

SplitFedV1-faithful: the server keeps a PER-CLIENT server-side copy trained
on that client's smashed stream; copies are FedAvg'd by the fed server at
round end. Client gradients come only from the server branch (no local
classifier); a stalled client (server unreachable) gets zero update.

  sfl — one rigid mid-stack split point for every client; clients whose
        Eq.1 capacity is below it cannot participate.
  dfl — resource-aware depths like ssfl (Samikwa et al.) but
        server-grad-only training and depth-weighted FedAvg.

Client-side optimizer state is per-round (clients re-download their
subnetwork), but the *server* moments persist across rounds in
``TrainState.opt_state["server"]``: each cohort broadcasts the shared
moments onto its per-client server copies and the post-round mean is folded
back — the moment-space analogue of SplitFed's FedAvg over server copies.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.federated import metrics as MET
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.models import model as M
from repro.optim import apply_updates


@functools.partial(jax.jit, static_argnames=("cfg", "d", "opt"))
def cohort_kernel(cfg: ModelConfig, d: int, opt,
                  client_stack, server_stack, local_p, batch_stack, avail,
                  eph_state, srv_state):
    """One server-grad-only step for a cohort sharing depth ``d``.

    ``eph_state`` covers the per-round client stack; ``srv_state`` is the
    persistent server moments broadcast onto the [Nc]-stacked copies.
    """

    def one(cp, sp, b, av):
        def loss_fn(cp_, sp_):
            full = SN.merge_params(cfg, cp_, sp_, local_p)
            z, _ = M.prefix_apply(cfg, full, b, d)
            return M.server_loss(cfg, full, z, b, d)

        loss, (gc, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        zero = lambda t: jax.tree.map(
            lambda g: jnp.where(av, g, jnp.zeros_like(g)), t)
        return zero(gc), zero(gs), loss

    gc, gs, loss = jax.vmap(one, in_axes=(0, 0, 0, 0))(
        client_stack, server_stack, batch_stack, avail)
    eph_updates, eph_state = opt.update(gc, eph_state, client_stack)
    srv_updates, new_srv_state = opt.update(gs, srv_state, server_stack)
    # a stalled client gets a bit-exact zero update on BOTH sides: its
    # zeroed gradient must not turn into a momentum-decay or weight-decay
    # step, and its carried server moments stay frozen (so they don't
    # contaminate the round-end mean); shared bookkeeping (step counter)
    # advances only if anyone is live
    row = lambda x: avail.reshape((-1,) + (1,) * (x.ndim - 1))
    zero_stalled = lambda tree: jax.tree.map(
        lambda u: jnp.where(row(u), u, jnp.zeros_like(u)), tree)
    eph_updates = zero_stalled(eph_updates)
    srv_updates = zero_stalled(srv_updates)
    srv_state = _gate_server_state(new_srv_state, srv_state, server_stack,
                                   avail)
    return (apply_updates(client_stack, eph_updates),
            apply_updates(server_stack, srv_updates),
            eph_state, srv_state, loss)


def _gate_server_state(new, old, params_stack, avail):
    """Per-client freeze of stacked server moments: keep the updated entry
    only for live clients; bookkeeping scalars advance iff any client is
    live. Mirrors the optimizer-state contract (``optim.map_moments``)."""
    if not isinstance(new, dict):
        return new
    row = lambda x: avail.reshape((-1,) + (1,) * (x.ndim - 1))
    anyav = jnp.any(avail)
    pdef = jax.tree_util.tree_structure(params_stack)
    out = {}
    for k, v in new.items():
        if jax.tree_util.tree_structure(v) == pdef:
            out[k] = jax.tree.map(lambda a, b: jnp.where(row(a), a, b),
                                  v, old[k])
        else:
            out[k] = jax.tree.map(lambda a, b: jnp.where(anyav, a, b),
                                  v, old[k])
    return out


class SplitFedBase(Strategy):
    """Shared SFL/DFL round logic; subclasses pick split + weighting."""

    def client_weights(self, depths, n: int):
        raise NotImplementedError

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        # accumulators for FedAvg over per-client server copies
        return {"client_trees": [None] * state.n_clients,
                "losses": np.zeros(state.n_clients),
                "num_stack": jax.tree.map(
                    lambda x: jnp.zeros_like(x, jnp.float32),
                    state.params[sname]),
                "den_rows": np.zeros(cfg.split_stack_len),
                "num_other": {},
                "den_other": 0}

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        client_p, server_p, local_p = SN.split_params(cfg, state.params, d)
        bcast = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), t)
        cstack, sstack = bcast(client_p), bcast(server_p)
        av = jnp.asarray(ctx.avail[ids])
        eph_state = engine.optimizer.init(cstack)
        srv_template, srv_full, srv_slice = base.cohort_server_opt(
            engine, cfg, sname, d)
        srv_state = base.broadcast_server_opt(srv_slice, server_p, len(ids))
        loss = None
        for _ in range(engine.local_steps):
            bstack = ctx.batch_fn(ids)
            cstack, sstack, eph_state, srv_state, loss = cohort_kernel(
                cfg, d, engine.optimizer, cstack, sstack, local_p, bstack,
                av, eph_state, srv_state)
        state.opt_state["server"] = base.merge_server_opt(
            srv_full, base.mean_server_opt(srv_state, server_p),
            srv_template, sname, d)
        for j, i in enumerate(ids):
            ws["client_trees"][i] = jax.tree.map(lambda x: x[j], cstack)
            ws["losses"][i] = float(loss[j])
        cparams = sum(int(x.size) for x in jax.tree.leaves(client_p))
        sparams = sum(int(x.size) for x in jax.tree.leaves(server_p))
        return CohortResult(cparams, sparams, payload=sstack)

    def fold_server(self, engine, ws, d, ids, res) -> None:
        """Fold this cohort's server copies into the FedAvg accumulators."""
        sname = SN.split_stack_name(engine.cfg)
        sstack = res.payload
        ws["num_stack"] = jax.tree.map(
            lambda acc, s: acc.at[d:].add(
                jnp.sum(s.astype(jnp.float32), axis=0)),
            ws["num_stack"], sstack[sname])
        ws["den_rows"][d:] += len(ids)
        for k, v in sstack.items():
            if k == sname:
                continue
            add = jax.tree.map(
                lambda x: jnp.sum(x.astype(jnp.float32), axis=0), v)
            ws["num_other"][k] = add if k not in ws["num_other"] \
                else jax.tree.map(lambda a, b: a + b, ws["num_other"][k], add)
        ws["den_other"] += len(ids)

    def aggregate(self, engine, ws):
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        # FedAvg the per-client server copies into the server view
        den_rows = ws["den_rows"]
        den = jnp.asarray(np.maximum(den_rows, 1e-9))
        server_view: Dict[str, Any] = {sname: jax.tree.map(
            lambda n, g: jnp.where(
                (den_rows > 0).reshape((-1,) + (1,) * (n.ndim - 1)),
                n / den.reshape((-1,) + (1,) * (n.ndim - 1)),
                g.astype(jnp.float32)).astype(g.dtype),
            ws["num_stack"], state.params[sname])}
        for k, v in ws["num_other"].items():
            server_view[k] = jax.tree.map(
                lambda n, g: (n / max(ws["den_other"], 1)).astype(g.dtype),
                v, state.params[k])
        return self._finish_aggregation(
            engine, ws, server_view,
            lambda g, s, d, l: AGG.aggregate_weighted(
                cfg, g, s, d, self.client_weights(d, len(d))))

    def comm_cost(self, engine, d, available):
        # SplitFed ships BOTH client- and server-side nets through the fed
        # server each round; a stalled client moves no useful bytes
        pbytes = MET.tree_bytes(engine.state.params)
        total = 2 * pbytes + 2 * engine.smashed_bytes(d) * engine.local_steps
        return (total if available else 0, 2 + 2 * engine.local_steps)


@register_strategy("sfl")
class SplitFed(SplitFedBase):

    def fixed_depth(self, cfg):
        # SplitFed's rigid split: one fixed point (mid-stack) for everyone
        return max(cfg.split_stack_len // 2, 1)

    def client_weights(self, depths, n: int):
        return jnp.full(n, 1.0 / n, jnp.float32)


@register_strategy("dfl")
class DynamicSplitFed(SplitFedBase):

    def client_weights(self, depths, n: int):
        return jnp.asarray(depths.astype(np.float32) / depths.sum())

"""SplitFed baselines (SFL + dynamic-split DFL) as engine strategies.

SplitFedV1-faithful: the server keeps a PER-CLIENT server-side copy trained
on that client's smashed stream; copies are FedAvg'd by the fed server at
round end. Client gradients come only from the server branch (no local
classifier); a stalled client (server unreachable) gets zero update.

  sfl — one rigid mid-stack split point for every client; clients whose
        Eq.1 capacity is below it cannot participate.
  dfl — resource-aware depths like ssfl (Samikwa et al.) but
        server-grad-only training and depth-weighted FedAvg.

Execution follows the bucketed device-resident kernel contract
(``federated.bucketing``): one scanned kernel per (width, bucket) runs all
local steps with on-device batch gather — depth is a RUNTIME scalar
(masked scan over the full stack, ``model.run_stack``), so dfl's
heterogeneous depth tiers share one compiled program. Padded slots ride
with ``avail=False`` (zero update, frozen moments) and are excluded from
the round-end FedAvg over server copies.

Client-side optimizer state is per-round (clients re-download their
subnetwork), but the *server* moments persist across rounds in
``TrainState.opt_state["server"]``: each cohort broadcasts the shared
moments onto its per-client server copies and the post-round mean is folded
back — the moment-space analogue of SplitFed's FedAvg over server copies.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.federated import bucketing as BK
from repro.federated import metrics as MET
from repro.federated.strategies import base
from repro.federated.strategies.base import (CohortResult, RoundContext,
                                             Strategy, register_strategy)
from repro.launch.sharding import P, slot_pspec
from repro.models import model as M
from repro.optim import apply_updates


def _cohort_specs(axes, d, client_stack, server_stack,
                  images, labels, idx, avail, valid, srv_state):
    """shard_map layout: client/server stacks and masks shard their slot
    axis; the runtime depth scalar and flat dataset replicate.
    ``srv_state`` mixes per-slot moment stacks (sharded) with shared
    bookkeeping scalars (replicated) — the split mirrors
    ``optim.map_moments``."""
    slot = slot_pspec(0, axes)
    sdef = jax.tree_util.tree_structure(server_stack)
    srv_spec = {k: (jax.tree.map(lambda _: slot, v)
                    if jax.tree_util.tree_structure(v) == sdef else
                    jax.tree.map(lambda _: P(), v))
                for k, v in srv_state.items()} \
        if isinstance(srv_state, dict) else P()
    in_specs = (P(), slot, slot, P(), P(), slot_pspec(1, axes),
                slot, slot, srv_spec)
    out_specs = (slot, slot, srv_spec, slot)
    return in_specs, out_specs


@BK.register_kernel(n_static=4, specs=_cohort_specs)
def cohort_kernel(cfg: ModelConfig, opt, steps: int, width: float, d,
                  client_stack, server_stack,
                  images, labels, idx, avail, valid, srv_state,
                  axis_name=None):
    """All ``steps`` server-grad-only steps for one padded cohort bucket
    sharing runtime depth ``d`` and width tier ``width``, as a single
    compiled scan.

    ``d`` is a RUNTIME jax scalar: both stacks hold all ``L`` split-stack
    rows per slot, the client/server forwards are the masked prefix/suffix
    scans (``model.run_stack``, bit-exact vs the static slices), and
    ``supernet.depth_freeze`` reverts every optimizer touch of an
    out-of-window row — one compiled program per (width, bucket) covers
    every depth tier. The ephemeral client-stack optimizer state
    initializes inside the kernel; ``srv_state`` is the persistent FULL
    server moments broadcast onto the [Nc]-stacked copies (rows ``< d``
    ride along frozen). ``avail`` is False on padded slots (they can
    never step), ``valid`` marks real clients. ``axis_name`` is bound to
    the fleet mesh axes under the shard-mapped variant, so the freeze gate
    sees every shard's slots. ``width`` is STATIC — the compile key is
    (width, bucket); at ``width < 1`` the client stack is the
    ``supernet.slice_width`` view and the forward runs on the slice.
    """

    wcfg = SN.width_cfg(cfg, width)
    anyav = BK.freeze_gate(avail, valid, axis_name)

    def one(cp, sp, b, av):
        def loss_fn(cp_, sp_):
            z, _ = M.client_apply(wcfg, cp_, b, length=d)
            return M.server_split_loss(cfg, sp_, z, b, length=d)

        loss, (gc, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        zero = lambda t: jax.tree.map(
            lambda g: jnp.where(av, g, jnp.zeros_like(g)), t)
        return zero(gc), zero(gs), loss

    def step(carry, idx_t):
        cstack, sstack, eph_state, s_state = carry
        BK.guard_gather(idx_t, images.shape[0])   # sanitize-mode OOB check
        batch = {"images": images[idx_t], "label": labels[idx_t]}
        gc, gs, loss = jax.vmap(one, in_axes=(0, 0, 0, 0))(
            cstack, sstack, batch, avail)
        eph_updates, eph_state = opt.update(gc, eph_state, cstack)
        srv_updates, new_s_state = opt.update(gs, s_state, sstack)
        # a stalled client gets a bit-exact zero update on BOTH sides: its
        # zeroed gradient must not turn into a momentum-decay or
        # weight-decay step, and its carried server moments stay frozen (so
        # they don't contaminate the round-end mean); shared bookkeeping
        # (step counter) advances only if anyone is live
        row = lambda x: avail.reshape((-1,) + (1,) * (x.ndim - 1))
        zero_stalled = lambda tree: jax.tree.map(
            lambda u: jnp.where(row(u), u, jnp.zeros_like(u)), tree)
        eph_updates = zero_stalled(eph_updates)
        srv_updates = zero_stalled(srv_updates)
        new_c = apply_updates(cstack, eph_updates)
        new_s = apply_updates(sstack, srv_updates)
        # runtime-depth row freeze: out-of-window rows of every per-slot
        # stack (params AND server moments) must be bit-exact no-ops so
        # the host's d=0 opt-state round trip and the fold accumulators
        # stay on the legacy contract
        new_c = SN.depth_freeze(cfg, new_c, cstack, d, keep="prefix",
                                axis=1)
        new_s = SN.depth_freeze(cfg, new_s, sstack, d, keep="suffix",
                                axis=1)
        new_s_state = _gate_server_state(new_s_state, s_state, sstack,
                                         avail, anyav)
        s_state = SN.depth_freeze(cfg, new_s_state, s_state, d,
                                  keep="suffix", axis=1)
        return ((new_c, new_s, eph_state, s_state), loss)

    eph_state = opt.init(client_stack)
    carry = (client_stack, server_stack, eph_state, srv_state)
    (cstack, sstack, _, srv_state), loss = jax.lax.scan(step, carry, idx)
    return cstack, sstack, srv_state, loss[-1]


def _gate_server_state(new, old, params_stack, avail, anyav):
    """Per-client freeze of stacked server moments: keep the updated entry
    only for live clients; bookkeeping scalars advance iff any real client
    is live. Mirrors the optimizer-state contract (``optim.map_moments``)."""
    if not isinstance(new, dict):
        return new
    row = lambda x: avail.reshape((-1,) + (1,) * (x.ndim - 1))
    pdef = jax.tree_util.tree_structure(params_stack)
    out = {}
    for k, v in new.items():
        if jax.tree_util.tree_structure(v) == pdef:
            out[k] = jax.tree.map(lambda a, b: jnp.where(row(a), a, b),
                                  v, old[k])
        else:
            out[k] = jax.tree.map(lambda a, b: jnp.where(anyav, a, b),
                                  v, old[k])
    return out


class SplitFedBase(Strategy):
    """Shared SFL/DFL round logic; subclasses pick split + weighting."""

    def client_weights(self, depths, mask):
        """[N] aggregation weights over the full fleet; ``mask`` marks the
        clients that trained this round (weights must be 0 elsewhere)."""
        raise NotImplementedError

    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        ws = base.fleet_workspace(engine)
        # accumulators for FedAvg over per-client server copies
        ws.update({"num_stack": jax.tree.map(
                       lambda x: jnp.zeros_like(x, jnp.float32),
                       state.params[sname]),
                   "den_rows": np.zeros(cfg.split_stack_len),
                   "num_other": {},
                   "den_other": 0})
        return ws

    def cohort_step(self, engine, ctx, ws, d, ids) -> CohortResult:
        """Split the depth-``d`` cohort into same-width sub-cohorts (the
        width is a static kernel arg — compile key (width, bucket)) and
        CHAIN them through the shared server moments: each group's
        per-client server copies start from the previous group's
        fed-averaged moments. Depth rides the kernel as a runtime scalar
        over full-``L`` views, so re-tiered fleets reuse the same
        compiled programs."""
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        client_p, server_p, _ = SN.split_params(cfg, state.params, None)
        srv_template, srv_full, srv_slice = base.cohort_server_opt(
            engine, cfg, sname, 0)
        folds, losses, csum = [], None, 0
        from repro.federated.strategies.ssfl import SuperSFL
        for w, gids in SuperSFL._width_groups(engine, ids):
            group_p = client_p if w >= 1.0 else \
                SN.split_params(cfg, state.params, None, w)[0]
            sstack, valid, srv_slice, losses = self._run_subcohort(
                engine, ctx, ws, d, gids, group_p, server_p,
                srv_slice, width=w)
            folds.append((sstack, valid, len(gids)))
            csum += len(gids) * base.split_param_counts(
                cfg, state.params, d, w)[0]
        state.opt_state["server"] = base.merge_server_opt(
            srv_full, srv_slice, srv_template, sname, 0)
        cparams = csum // max(len(ids), 1)
        sparams = base.split_param_counts(cfg, state.params, d)[1]
        return CohortResult(cparams, sparams, payload=folds, losses=losses)

    def _run_subcohort(self, engine, ctx, ws, d, ids, client_p, server_p,
                       srv_slice, width: float = 1.0):
        """One bucketed kernel call for a same-width group: broadcast the
        full server view/moments onto per-client copies, run all local
        steps, fed-average the moments back (rows ``< d`` are restored
        from the chained input — the kernel froze them, and a
        mean-of-identical-copies is not guaranteed bit-exact). ``client_p``
        must already be the width-``width`` slice when ``width < 1``.
        Returns ``(sstack, valid, srv_slice, losses)`` so callers can
        chain groups through the shared moments."""
        cfg, state = engine.cfg, engine.state
        n = state.n_clients
        bucket = engine.bucket_for(len(ids))
        pids = jnp.asarray(BK.pad_ids(np.asarray(ids), bucket, n))
        valid = jnp.asarray(np.arange(bucket) < len(ids))
        avail = jnp.asarray(BK.pad_rows(
            np.asarray(ctx.avail[ids], bool), bucket, fill=False))
        idx = jnp.asarray(BK.pad_slot_axis(
            ctx.sample_indices(ids, engine.local_steps, engine.batch_size),
            bucket, axis=1))
        bcast = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (bucket,) + x.shape), t)
        cstack, sstack = bcast(client_p), bcast(server_p)
        srv_state = base.broadcast_server_opt(srv_slice, server_p, bucket)
        dd = engine.device_data
        kernel = engine.kernel_fn(cohort_kernel, bucket)
        cstack, sstack, srv_state, loss = kernel(
            cfg, engine.optimizer, engine.local_steps, width,
            jnp.int32(d), cstack, sstack, dd.images, dd.labels, idx,
            avail, valid, srv_state)
        srv_mean = base.mean_server_opt(srv_state, server_p, valid=valid)
        srv_slice = SN.depth_freeze(cfg, srv_mean, srv_slice, d,
                                    keep="suffix")
        base.scatter_client_rows(cfg, ws, pids, cstack, d, width)
        base.record_cohort(ws, pids, loss)
        return sstack, valid, srv_slice, loss

    def fold_server(self, engine, ws, d, ids, res) -> None:
        """Fold each sub-cohort's server copies into the FedAvg
        accumulators (padded bucket slots are masked out of every sum).
        The payload stacks are full-``L`` (runtime-depth kernel); only the
        trained suffix rows [d:] accumulate — rows < d are frozen
        broadcast copies."""
        sname = SN.split_stack_name(engine.cfg)
        for sstack, valid, count in res.payload:
            msum = lambda x: jnp.sum(
                jnp.where(valid.reshape((-1,) + (1,) * (x.ndim - 1)),
                          x.astype(jnp.float32), 0.0), axis=0)
            ws["num_stack"] = jax.tree.map(
                lambda acc, s: acc.at[d:].add(msum(s)[d:]),
                ws["num_stack"], sstack[sname])
            ws["den_rows"][d:] += count
            for k, v in sstack.items():
                if k == sname:
                    continue
                add = jax.tree.map(msum, v)
                ws["num_other"][k] = add if k not in ws["num_other"] \
                    else jax.tree.map(lambda a, b: a + b,
                                      ws["num_other"][k], add)
            ws["den_other"] += count

    def aggregate(self, engine, ws):
        cfg, state = engine.cfg, engine.state
        sname = SN.split_stack_name(cfg)
        # FedAvg the per-client server copies into the server view
        den_rows = ws["den_rows"]
        den = jnp.asarray(np.maximum(den_rows, 1e-9))
        server_view: Dict[str, Any] = {sname: jax.tree.map(
            lambda n, g: jnp.where(
                (den_rows > 0).reshape((-1,) + (1,) * (n.ndim - 1)),
                n / den.reshape((-1,) + (1,) * (n.ndim - 1)),
                g.astype(jnp.float32)).astype(g.dtype),
            ws["num_stack"], state.params[sname])}
        for k, v in ws["num_other"].items():
            server_view[k] = jax.tree.map(
                lambda n, g: (n / max(ws["den_other"], 1)).astype(g.dtype),
                v, state.params[k])
        widths = getattr(state.fleet, "widths", None)
        return self._finish_aggregation(
            engine, ws, server_view,
            lambda g, s, dep, l, m: AGG.aggregate_weighted(
                cfg, g, s, dep, self.client_weights(dep, m), mask=m,
                widths=widths))

    def comm_cost(self, engine, d, available, ids=None):
        # SplitFed ships BOTH client- and server-side nets through the fed
        # server each round; a stalled client moves no useful bytes
        pbytes = MET.tree_bytes(engine.state.params)
        total = 2 * pbytes + 2 * engine.smashed_bytes(d) * engine.local_steps
        return (total if available else 0, 2 + 2 * engine.local_steps)


@register_strategy("sfl")
class SplitFed(SplitFedBase):

    def fixed_depth(self, cfg):
        # SplitFed's rigid split: one fixed point (mid-stack) for everyone
        return max(cfg.split_stack_len // 2, 1)

    def client_weights(self, depths, mask):
        mask = np.asarray(mask, np.float32)
        return jnp.asarray(mask / mask.sum())


@register_strategy("dfl")
class DynamicSplitFed(SplitFedBase):

    def client_weights(self, depths, mask):
        w = depths.astype(np.float32) * np.asarray(mask, np.float32)
        return jnp.asarray(w / w.sum())

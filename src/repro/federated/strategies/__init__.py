from repro.federated.strategies.base import (  # noqa: F401
    CohortResult, RoundContext, Strategy, available_strategies,
    get_strategy, register_strategy)
# importing the built-ins registers them
from repro.federated.strategies import (  # noqa: F401
    async_buffered, fedavg, hasfl, splitfed, ssfl, unstable)

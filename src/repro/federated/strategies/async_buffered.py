"""Buffered-async server aggregation (FedBuff-style) as an engine strategy.

``unstable`` already *weights* by staleness; this strategy changes *when*
the global model moves. Cohort results no longer fold into the globals at
round end — they are converted to staleness-tagged deltas and pushed into
the server-side :mod:`repro.federated.buffer` (capacity-K, flush
policies). The globals advance only when the buffer flushes: the buffered
deltas collapse under the standard ``(1 + s)^-gamma`` discount into one
aggregate pseudo-gradient, which steps through a pluggable **server
optimizer** — plain SGD, or the FedOpt family (``fedadam`` / ``fedyogi``,
Reddi et al.) — whose moments persist across rounds and checkpoints in
``TrainState.opt_state["server_fedopt"]``.

Two server-side optimizer states coexist, on purpose:

  * ``opt_state["server"]``       — the KERNEL-level moments of
    ``engine.optimizer``, stepping the shared server branch inside
    ``cohort_step`` every local step (owned by the inherited SuperSFL
    kernels; see ``strategies.base.server_opt_state``). Server compute
    keeps running between flushes — that is the async point.
  * ``opt_state["server_fedopt"]`` — THIS strategy's aggregation-time
    FedOpt moments, applied to the flushed pseudo-gradient. A separate
    slot because ``server_opt_state`` re-validates (and would
    re-initialize) ``"server"`` against ``engine.optimizer``'s shape.

Entry granularity is the **cohort**: ``fold_server`` records each
cohort's membership and its OWN server view — the cohort's server result
laid over the round-start stack, NOT the round's cumulative streamed view.
``aggregate`` then computes, per cohort, the staleness-weighted Eq. 6/8
candidate model restricted to that cohort's trained clients, and pushes
``candidate - globals`` tagged with the cohort's mean staleness and the
push round (all of a round's entries are relative to the same round-start
snapshot — cohorts are concurrent, and each entry carries only its own
cohort's server movement, so a round whose entries split across two
flushes never applies the shared server delta twice). The flush condition
is checked after every push, so the ``"count"`` policy fires at exactly K
arrivals — mid-round if cohorts fill the buffer — and the flush discount
adds each entry's *age in the buffer* on top of its tag: a delta that
waited 3 rounds is discounted as 3 rounds staler. FedBuff's staleness
rule at cohort granularity.

Invariants inherited and preserved (pinned in
``tests/test_async_buffer.py``):

  * frozen server — with the server unreachable from round 0, server-side
    leaves and the kernel server moments stay BIT-exact through pushes and
    flushes (deltas on those leaves are exactly zero, and zero
    pseudo-gradients are fixed points of sgd/fedadam/fedyogi from zero
    moments);
  * padded-slot contract — the bucketed kernels are inherited unchanged,
    so ladder vs exact bucketing agree;
  * bit-identical resume — the buffer and both server optimizer states
    live in ``opt_state``, so ``Engine.save``/``restore`` replays the
    push/flush schedule exactly.

Degenerate corner: ``BufferedAsync(capacity=1, policy="round",
server_opt="sgd", server_lr=1.0)`` on a single-depth fleet flushes each
entry immediately and undiscounted — it recovers ``unstable`` up to the
float round-trip ``params + (agg - params)``.
"""
from __future__ import annotations

from typing import Any, Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.federated import buffer as BUF
from repro.federated.strategies import base
from repro.federated.strategies.base import RoundContext, register_strategy
from repro.federated.strategies.unstable import (UnstableParticipation,
                                                 staleness_weights)
from repro.optim import Optimizer, apply_updates, get_optimizer

FEDOPT_SLOT = "server_fedopt"


@register_strategy("async_buffered")
class BufferedAsync(UnstableParticipation):
    """SuperSFL under Markov participation + FedBuff buffered folding.

    ``capacity`` / ``policy`` / ``max_age`` configure the buffer (see
    :mod:`repro.federated.buffer`); ``gamma`` drives BOTH the inherited
    per-client staleness weighting inside each cohort candidate and the
    flush-time discount across buffered entries; ``server_opt`` /
    ``server_lr`` pick the flush optimizer (``"sgd"``, ``"fedadam"``,
    ``"fedyogi"``, or any ``repro.optim.Optimizer`` instance)::

        Engine(cfg, 16, BufferedAsync(capacity=4, server_opt="fedyogi",
                                      server_lr=0.3))
    """

    def __init__(self, capacity: int = 4, policy: str = "count",
                 max_age: int = None,
                 server_opt: Union[str, Optimizer] = "sgd",
                 server_lr: float = 1.0,
                 p_up: float = 0.4, p_down: float = 0.2,
                 straggle_p: float = 0.1, gamma: float = 1.0):
        super().__init__(p_up=p_up, p_down=p_down, straggle_p=straggle_p,
                         gamma=gamma)
        if policy not in BUF.POLICIES:
            raise ValueError(f"unknown flush policy {policy!r}; "
                             f"available: {BUF.POLICIES}")
        if policy == "age" and max_age is None:
            raise ValueError("policy='age' requires max_age")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity, self.policy, self.max_age = capacity, policy, max_age
        self._server_opt = (get_optimizer(server_opt, server_lr)
                            if isinstance(server_opt, str) else server_opt)
        self.flushes = 0      # lifetime flush counter (bench/diagnostics)

    # ------------------------------------------------------- round phases
    def init_round(self, engine, ctx: RoundContext) -> Dict[str, Any]:
        ws = super().init_round(engine, ctx)
        ws["cohort_ids"] = {}
        ws["cohort_views"] = {}
        return ws

    def fold_server(self, engine, ws, d, ids, res) -> None:
        """Record the cohort's membership and its OWN server view: the
        cohort's trained suffix rows ``[d:]`` (the payload stack is
        full-``L`` under the runtime-depth kernels — rows ``< d`` rode
        along frozen) + non-stack leaves, laid over the ROUND-START
        stack. Deliberately not the cumulative ssfl streaming fold —
        entries of one round may flush at different times, and a shared
        streamed view would let a flush re-apply another cohort's server
        movement (once per flush it appears in)."""
        sname = SN.split_stack_name(engine.cfg)
        params = engine.state.params
        view = {sname: jax.tree.map(
            lambda full, nd: jnp.concatenate([full[:d], nd[d:]], axis=0),
            params[sname], res.payload[sname])}
        for k, v in res.payload.items():
            if k != sname:
                view[k] = v
        ws["cohort_views"][d] = view
        ws["cohort_ids"][d] = np.asarray(ids)

    def aggregate(self, engine, ws):
        state = engine.state
        # the ONE host sync of the round's training outputs (the same sync
        # _finish_aggregation would have done)
        mask, losses = jax.device_get((ws["trained"], ws["losses"]))
        loss = float(np.mean(losses[mask])) if mask.any() else float("nan")
        buf = self._buffer_state(engine)
        new_params = state.params
        if mask.any():
            ws["participated"] = np.where(mask)[0]
            stale = np.asarray(ws["staleness"], np.float64)
            for d, ids in ws["cohort_ids"].items():
                entry = self._cohort_entry(engine, ws, mask, stale, d, ids)
                if entry is None:
                    continue
                buf = BUF.push(buf, *entry, round_idx=state.round_idx)
                # flush check per push: the count policy fires at exactly
                # K arrivals (FedBuff), never silently ring-dropping
                new_params, buf = self._maybe_flush(engine, new_params,
                                                    buf)
        else:
            # no pushes this round; the age policy may still force a flush
            new_params, buf = self._maybe_flush(engine, new_params, buf)
        state.opt_state[BUF.SLOT] = buf
        return new_params, loss

    # --------------------------------------------------- buffered folding
    def _cohort_entry(self, engine, ws, mask, stale, d, ids):
        """One buffer entry for one cohort: the staleness-weighted Eq. 6/8
        candidate restricted to the cohort's trained clients — with the
        cohort's own server view merged over the round-start globals —
        minus those globals (every entry of a round is relative to the
        same snapshot — cohorts are concurrent, not sequential). Weight =
        trained count; tag = mean staleness. None if nobody trained."""
        state = engine.state
        cmask = np.zeros_like(mask)
        cmask[ids] = True
        cmask &= mask
        if not cmask.any():
            return None
        globals_with_server = dict(state.params)
        globals_with_server.update(ws["cohort_views"][d])
        w = np.asarray(AGG.client_weights(
            state.fleet.depths, ws["losses"], engine.cfg.tpgf_eps,
            mask=cmask))
        w = staleness_weights(w, stale, self.gamma, mask=cmask)
        cand = AGG.aggregate_weighted(
            engine.cfg, globals_with_server, ws["client_stack"],
            state.fleet.depths, np.asarray(w, np.float32), mask=cmask)
        delta = jax.tree.map(
            lambda c, p: c.astype(jnp.float32) - p.astype(jnp.float32),
            cand, state.params)
        return delta, float(cmask.sum()), float(stale[cmask].mean())

    def _maybe_flush(self, engine, params, buf):
        """Flush if the policy says so: collapse the buffered entries
        under the staleness discount and step ``params`` through the
        persistent FedOpt server optimizer (pseudo-gradient = -delta, so
        plain SGD at server_lr=1.0 applies the delta verbatim). Returns
        the (possibly unchanged) params and buffer."""
        state = engine.state
        if not BUF.ready(buf, policy=self.policy, max_age=self.max_age,
                         round_idx=state.round_idx):
            return params, buf
        delta, buf = BUF.flush(buf, gamma=self.gamma,
                               round_idx=state.round_idx)
        cur = state.opt_state.get(FEDOPT_SLOT)
        opt_id = id(self._server_opt)
        if cur is None or getattr(engine, "_fedopt_ok", None) != opt_id:
            want = jax.eval_shape(self._server_opt.init, params)
            if cur is None or not base._state_like(cur, want):
                cur = self._server_opt.init(params)
            engine._fedopt_ok = opt_id
        pseudo_grad = jax.tree.map(lambda d: -d, delta)
        updates, cur = self._server_opt.update(pseudo_grad, cur, params)
        state.opt_state[FEDOPT_SLOT] = cur
        self.flushes += 1
        return apply_updates(params, updates), buf

    def _buffer_state(self, engine):
        """The persistent buffer out of ``opt_state["update_buffer"]``,
        lazily (re)initialized when absent or shape-mismatched (different
        capacity / model). Validation runs once per (engine, strategy) and
        after every ``Engine.restore`` — the ``_server_opt_ok``
        discipline. Restored numpy leaves are re-placed as jnp arrays so
        pushes (``.at[]``) work directly on them."""
        cur = engine.state.opt_state.get(BUF.SLOT)
        if cur is not None and getattr(engine, "_buffer_ok",
                                       None) == id(self):
            return cur
        want = jax.eval_shape(
            lambda t: BUF.init_buffer(t, self.capacity), engine.state.params)
        if cur is None or not base._state_like(cur, want):
            cur = BUF.init_buffer(engine.state.params, self.capacity)
        else:
            cur = jax.tree.map(jnp.asarray, cur)
        engine.state.opt_state[BUF.SLOT] = cur
        engine._buffer_ok = id(self)
        return cur

from repro.federated.engine import Engine, EngineBuilder, predict  # noqa: F401
from repro.federated.round import FederatedTrainer  # noqa: F401
from repro.federated.simulator import Fleet, make_fleet  # noqa: F401
from repro.federated.state import TrainState, init_train_state  # noqa: F401
from repro.federated.strategies import (  # noqa: F401
    Strategy, available_strategies, get_strategy, register_strategy)
from repro.federated import metrics  # noqa: F401

from repro.federated.round import FederatedTrainer, predict  # noqa: F401
from repro.federated.simulator import Fleet, make_fleet  # noqa: F401
from repro.federated import metrics  # noqa: F401

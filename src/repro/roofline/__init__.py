from repro.roofline.analysis import (collective_bytes, roofline_terms,
                                     model_flops, HW)  # noqa: F401

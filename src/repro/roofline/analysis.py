"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants, all PER-CHIP:

    compute    = dot_FLOPs_per_chip / 197e12           [bf16 peak]
    memory     = hbm_traffic_per_chip / 819e9          [HBM bw]
    collective = wire_bytes_per_chip / 50e9            [ICI per link]

CALIBRATION (measured, see tests/test_roofline.py): jax's
``compiled.cost_analysis()`` reports PER-DEVICE numbers and counts each
while-loop body exactly ONCE — i.e. a 64-layer ``lax.scan`` contributes one
layer's FLOPs. So we parse the post-partitioning ``compiled.as_text()``
ourselves:

  - dot FLOPs: every ``dot`` op's 2 * prod(result dims) * contracted size,
    times the trip count of the enclosing while loop (recovered from the
    loop condition's comparison constant). Matmuls dominate every workload
    here, so dot-FLOPs ~= total FLOPs.
  - HBM traffic: sum of result-shape bytes of all ops (x2 for read+write,
    a standard proxy), trip-count corrected.
  - wire bytes: collective ops' result bytes (per-partition shapes) times
    an op wire factor (all-reduce 2x for ring reduce+broadcast, others 1x),
    trip-count corrected.

Raw ``cost_analysis`` numbers are kept in the record for reference.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_WIRE_FACTOR = {
    "all-reduce": 2.0,        # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9\[\],\{\} ()]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)


class HW:
    """TPU v5e-class hardware constants (per chip)."""
    PEAK_FLOPS = 197e12          # bf16
    HBM_BW = 819e9               # bytes/s
    ICI_BW = 50e9                # bytes/s per link
    HBM_BYTES = 16e9


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computation_blocks(hlo: str) -> Dict[str, str]:
    """Split HLO text into computation-name -> body blocks."""
    blocks: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        if (line.startswith("%") or line.startswith("ENTRY")
                or (line and not line[0].isspace()
                    and "{" in line and "(" in line)):
            if cur_name is not None:
                blocks[cur_name] = "\n".join(cur_lines)
            header = line.split("(")[0].strip()
            cur_name = header.split()[-1].lstrip("%")
            cur_lines = [line]
        else:
            cur_lines.append(line)
    if cur_name is not None:
        blocks[cur_name] = "\n".join(cur_lines)
    return blocks


def _while_trip_counts(hlo: str, blocks: Dict[str, str]) -> Dict[str, int]:
    """Map while-BODY computation name -> trip count.

    Primary source: XLA's ``backend_config={"known_trip_count":{"n":"L"}}``
    on the while op; fallback: the largest integer constant in the loop
    condition computation.
    """
    out: Dict[str, int] = {}
    for line in hlo.splitlines():
        if " while(" not in line:
            continue
        bm = re.search(r"body=%?([\w.\-]+)", line)
        if not bm:
            continue
        body = bm.group(1)
        trip = None
        tm = re.search(r'known_trip_count[^0-9]*(\d+)', line)
        if tm:
            trip = int(tm.group(1))
        else:
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            if cm:
                consts = re.findall(r"constant\((\d+)\)",
                                    blocks.get(cm.group(1), ""))
                if consts:
                    trip = max(int(c) for c in consts)
        out[body] = max(out.get(body, 1), trip or 1)
    return out


_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*\(?(\w+)\[([\d,]*)\]")


def _symbol_shapes(body: str) -> Dict[str, list]:
    """name -> result dims for every op definition in a computation."""
    syms: Dict[str, list] = {}
    for line in body.splitlines():
        ls = line.strip()
        m = _DEF_RE.match(ls.lstrip("ROOT ").strip())
        if m:
            syms[m.group(1)] = [int(d) for d in m.group(3).split(",") if d]
    return syms


def _nested_trip_multipliers(hlo: str, blocks: Dict[str, str],
                             trips: Dict[str, int]) -> Dict[str, int]:
    """Effective execution multiplier per computation, following nesting
    (a scan inside a scan multiplies). Computations called from a while body
    (fusions, regions) inherit the body's multiplier."""
    # build call edges: computation -> computations it references
    call_re = re.compile(
        r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{|"
        r"called_computations=\{)%?([\w.\-]+)")
    edges: Dict[str, list] = {}
    for name, body in blocks.items():
        edges[name] = call_re.findall(body)
    mult: Dict[str, int] = {}

    def visit(name, m):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for child in edges.get(name, []):
            # a while body's ops run `trip` times relative to the caller
            visit(child, m * trips.get(child, 1))

    roots = [n for n in blocks if n.startswith("main") or "ENTRY" in
             blocks[n].splitlines()[0]]
    if not roots:
        roots = list(blocks)[:1]
    for r in roots:
        visit(r, 1)
    # unvisited computations (shouldn't happen): multiplier from trips
    for n in blocks:
        mult.setdefault(n, trips.get(n, 1))
    return mult


# Both operand spellings XLA has used in HLO text: the bare symbol form
# ``dot(%lhs, %rhs)`` and the typed form ``dot(f32[64,512]{1,0} %lhs, ...)``
# (jax >= 0.4.3x CPU emits the latter) — the optional group skips the
# operand's dtype[shape]{layout} prefix so the lhs *symbol* is captured.
_DOT_LINE_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\bdot\(\s*"
    r"(?:[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?\s+)?%?([\w.\-]+),")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims(s: str):
    return [int(d) for d in s.split(",") if d]


def dot_flops(hlo: str) -> float:
    """Per-chip matmul FLOPs, trip-count corrected."""
    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo, blocks)
    mult = _nested_trip_multipliers(hlo, blocks, trips)
    total = 0.0
    for name, body in blocks.items():
        m_ = mult.get(name, 1)
        syms = None
        for line in body.splitlines():
            dm_ = _DOT_LINE_RE.search(line)
            if not dm_:
                continue
            res = _dims(dm_.group(2))
            lhs_name = dm_.group(3)
            if syms is None:
                syms = _symbol_shapes(body)
            lhs = syms.get(lhs_name, [])
            cm = _LHS_C_RE.search(line)
            contracted = 1
            if cm and lhs:
                for idx in _dims(cm.group(1)):
                    if idx < len(lhs):
                        contracted *= lhs[idx]
            n = 1
            for d in res:
                n *= d
            total += 2.0 * n * contracted * m_
    return total


def hbm_traffic(hlo: str) -> float:
    """Per-chip HBM byte-traffic proxy: 2x result bytes of every op in the
    entry + loop bodies, trip-count corrected. Fusions collapse their body
    ops into one result write, which is exactly what we want to count."""
    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo, blocks)
    mult = _nested_trip_multipliers(hlo, blocks, trips)
    total = 0.0
    skip = ("parameter(", "constant(", "tuple(", "get-tuple-element")
    for name, body in blocks.items():
        header = body.splitlines()[0] if body else ""
        if "fused_computation" in name or name.startswith("region_") and \
                "fusion" in header:
            continue
        m_ = mult.get(name, 1)
        for line in body.splitlines():
            ls = line.strip()
            if not ls.startswith("%") and not ls.startswith("ROOT"):
                continue
            if any(s in ls for s in skip):
                continue
            eq = ls.find("=")
            if eq < 0:
                continue
            total += 2.0 * _shape_bytes(ls[eq:eq + 200].split("(")[0]) * m_
    return total


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind (trip-count aware)."""
    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo, blocks)
    mult = _nested_trip_multipliers(hlo, blocks, trips)
    by_kind: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_WIRE_FACTOR}
    for name, body in blocks.items():
        m_ = mult.get(name, trips.get(name, 1))
        for line in body.splitlines():
            m = _OP_RE.search(line)
            if not m:
                continue
            shape_str, kind = m.group(1), m.group(2).lower()
            nbytes = _shape_bytes(shape_str)
            by_kind[kind] += (nbytes * COLLECTIVE_WIRE_FACTOR[kind] * m_)
    by_kind["total"] = sum(v for k, v in by_kind.items())
    return by_kind


def model_flops(cfg, shape, n_params: int, n_active_params: int) -> float:
    """6 N D (train) / 2 N D (inference); N = active params for MoE."""
    if cfg.family == "vlm":
        tokens = shape.global_batch * shape.seq_len
    elif cfg.is_encdec:
        tokens = shape.global_batch * (shape.seq_len + cfg.enc_frames)
    else:
        tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch * 1
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active_params * tokens


def active_params(cfg, n_params: int) -> int:
    if cfg.n_experts and cfg.top_k:
        # expert weights used per token: top_k / n_experts of expert params
        # expert params dominate; approximate by scaling the MoE share
        expert_share = 3 * cfg.n_layers * cfg.n_experts * cfg.d_model * cfg.d_ff
        dense_rest = n_params - expert_share
        return int(dense_rest + expert_share * cfg.top_k / cfg.n_experts)
    return n_params


def roofline_terms(cost: Dict, hlo: str, chips: int) -> Dict[str, float]:
    flops = dot_flops(hlo)                       # per-chip, trip-corrected
    # HBM traffic: raw cost_analysis bytes (per-chip, loop bodies counted
    # once) scaled by the trip-count undercount ratio measured on FLOPs —
    # the workload's own loop structure calibrates the correction. The raw
    # line-level proxy (hbm_traffic) overcounts on the CPU backend (weaker
    # fusion than TPU), so it is recorded but not used for the term.
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    trip_ratio = max(1.0, flops / raw_flops) if raw_flops else 1.0
    bytes_ = raw_bytes * trip_ratio
    coll = collective_bytes(hlo)
    t_compute = flops / HW.PEAK_FLOPS
    t_memory = bytes_ / HW.HBM_BW
    t_coll = coll["total"] / HW.ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"flops": flops * chips,              # global, for 6ND comparison
            "flops_per_chip": flops,
            "bytes_per_chip": bytes_,
            "line_proxy_bytes_per_chip": hbm_traffic(hlo),
            "raw_cost_flops": raw_flops,
            "raw_cost_bytes": raw_bytes,
            "collective_wire_bytes_per_chip": coll["total"],
            "collectives": {k: v for k, v in coll.items() if k != "total"},
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}

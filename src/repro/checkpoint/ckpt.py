"""Flat-npz checkpointing with a JSON manifest (offline, no orbax).

A checkpoint is two sibling files: ``<path>.npz`` holding every array leaf
under a ``/``-joined tree path, and ``<path>.json`` recording the step,
caller metadata, and each leaf's dtype/shape. Empty containers flatten to
nothing (callers lazily re-initialize, e.g. stateless optimizer slots).
``load_checkpoint`` validates the npz payload against the manifest so a
truncated or mismatched pair fails loudly instead of restoring garbage.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

FORMAT_VERSION = 1


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_checkpoint(path: str, params: Dict[str, Any], *,
                    step: int = 0, meta: Dict[str, Any] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(params))
    np.savez(path + ".npz", **flat)
    manifest = {"format": FORMAT_VERSION, "step": step, "meta": meta or {},
                "keys": sorted(flat.keys()),
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                "shapes": {k: list(v.shape) for k, v in flat.items()}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    missing = sorted(set(manifest["keys"]) - set(data.files))
    if missing:
        raise ValueError(f"checkpoint {path!r}: manifest lists "
                         f"{len(missing)} arrays absent from the npz "
                         f"payload, e.g. {missing[:3]}")
    tree: Dict[str, Any] = {}
    for key in manifest["keys"]:
        arr = data[key]
        want_shape = tuple(manifest["shapes"][key])
        if arr.shape != want_shape:
            raise ValueError(f"checkpoint {path!r}: {key} has shape "
                             f"{arr.shape}, manifest says {want_shape}")
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree, manifest

"""Oracle: plain attention with causal/sliding-window masks and GQA."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import attention, make_attn_mask


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,Sq,H,hd]; k,v [B,Skv,K,hd] -> [B,Sq,H,hd]."""
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    pos_k = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    mask = make_attn_mask(pos_q, pos_k, causal=causal, window=window)
    return attention(q, k, v, mask=mask)

"""Jitted wrapper matching the model's [B, S, H, hd] attention layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K

_INTERPRET = True


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret=None):
    """q [B,Sq,H,hd]; k,v [B,Skv,Kh,hd] -> [B,Sq,H,hd]."""
    interpret = _INTERPRET if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = K.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                 interpret=interpret)
    return jnp.swapaxes(out, 1, 2)

"""Pallas TPU flash attention (causal + sliding-window, GQA-aware).

Online-softmax formulation: grid (B, H, n_q_blocks, n_kv_blocks) with the
kv-block axis innermost — TPU grids iterate sequentially, so the running
max/denominator/accumulator live in VMEM scratch carried across kv steps
(the canonical TPU flash pattern; no atomics, no HBM round-trips for the
softmax statistics).

GQA is handled in the BlockSpec index_map: query head h reads kv head
h * K // H — no materialized head repetition.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(meta_ref, q_ref, k_ref, v_ref, out_ref,
                 m_ref, l_ref, acc_ref, *, bq, bk, causal, window, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)
    scale = meta_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    s = (q @ k.T) * scale                           # [bq, bk]

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask = mask & (cols <= rows)
    if window:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q [B,H,Sq,hd]; k,v [B,K,Skv,hd] (H % K == 0). Returns [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    Skv = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    meta = jnp.asarray([1.0 / math.sqrt(hd)], jnp.float32)
    kv_map = lambda b, h, i, j: (b, h * K // H, j, 0)
    return pl.pallas_call(
        functools.partial(_attn_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(meta, q, k, v)

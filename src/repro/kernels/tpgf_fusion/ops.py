"""Jitted wrappers: pytree-level TPGF fusion on top of the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.tpgf_fusion import kernel as K

_INTERPRET = True  # CPU container: interpret-mode; flips to False on TPU


def _to_tiles(x):
    """Flatten to [M, LANE] padded to ROW_BLOCK rows; remember true size."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_block = K.ROW_BLOCK * K.LANE
    padded = ((n + per_block - 1) // per_block) * per_block
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, K.LANE), n


def fuse_leaf(a, b, w_client, clip_scale, *, interpret=None):
    interpret = _INTERPRET if interpret is None else interpret
    ta, n = _to_tiles(a)
    tb, _ = _to_tiles(b)
    out = K.fuse_2d(ta, tb, w_client, clip_scale, interpret=interpret)
    return out.reshape(-1)[:n].reshape(a.shape)


def tier_sum_leaf(leaves, weights, *, interpret=None):
    """``sum_t weights[t] * leaves[t]`` for one leaf shape across tiers.

    ``leaves`` are same-shape full-width (already lifted) arrays, one per
    tier in canonical order; ``weights`` the matching normalized fp32
    scalars. Tiles each leaf, stacks the tier axis, and runs the one-pass
    ``tier_sum_2d`` accumulator. Returns fp32 (``fuse_tiers`` casts)."""
    interpret = _INTERPRET if interpret is None else interpret
    tiles, n = zip(*(_to_tiles(x) for x in leaves))
    out = K.tier_sum_2d(jnp.stack(tiles), jnp.stack(weights),
                        interpret=interpret)
    return out.reshape(-1)[:n[0]].reshape(leaves[0].shape)


def fuse_tree(g_client, g_server, w_client, *, tau: float = None,
              interpret=None):
    """Eq. 4 over a pytree. If ``tau`` is given, also computes the global-l2
    clip scale with the sumsq kernel (Phase-1 clip fused into the blend)."""
    interpret = _INTERPRET if interpret is None else interpret
    if tau is not None:
        total = jnp.float32(0.0)
        for leaf in jax.tree.leaves(g_client):
            t, n = _to_tiles(leaf)
            total = total + K.sumsq_2d(t, interpret=interpret)
        norm = jnp.sqrt(total)
        clip_scale = jnp.minimum(1.0, tau / (norm + 1e-12))
    else:
        clip_scale = jnp.float32(1.0)
    return jax.tree.map(
        lambda a, b: fuse_leaf(a, b, w_client, clip_scale,
                               interpret=interpret),
        g_client, g_server)

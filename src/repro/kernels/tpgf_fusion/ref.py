"""Pure-jnp oracle for the TPGF fusion kernel.

Semantics (paper Eq. 4 + Phase-1 clip): given the two encoder gradients and
precomputed scalars, produce
    out = w_client * (g_client * clip_scale) + (1 - w_client) * g_server
in one pass. ``clip_scale`` is the global-l2 clip factor min(1, tau/||g||).
"""
from __future__ import annotations

import jax.numpy as jnp


def fuse(g_client, g_server, w_client, clip_scale):
    a = g_client.astype(jnp.float32)
    b = g_server.astype(jnp.float32)
    out = w_client * (a * clip_scale) + (1.0 - w_client) * b
    return out.astype(g_client.dtype)


def sumsq(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))

"""Pallas TPU kernel: fused clip-scale + loss-weighted gradient blend.

TPGF Phase 3 (Eq. 4) touches every client-encoder gradient element twice in
the naive form (clip multiply, then blend) — two full HBM round-trips over
the gradient pytree. This kernel fuses them into one pass:

    out = w * (g_client * clip_scale) + (1 - w) * g_server

Layout: leaves are flattened and padded to (rows, 128) fp32/bf16 tiles;
the grid walks row-blocks, with the two scalars in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROW_BLOCK = 256


def _fuse_kernel(scalars_ref, a_ref, b_ref, out_ref):
    w = scalars_ref[0]
    cs = scalars_ref[1]
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    out_ref[...] = (w * (a * cs) + (1.0 - w) * b).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fuse_2d(a, b, w_client, clip_scale, *, interpret: bool = True):
    """a, b: [M, 128k] with M % ROW_BLOCK == 0 (callers pad via ops.py)."""
    M, N = a.shape
    grid = (M // ROW_BLOCK,)
    scalars = jnp.stack([jnp.float32(w_client), jnp.float32(clip_scale)])
    return pl.pallas_call(
        _fuse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # scalars, prefetched whole
            pl.BlockSpec((ROW_BLOCK, N), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, N), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(scalars, a, b)


def _tier_sum_kernel(w_ref, x_ref, out_ref):
    t = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[t] * x


@functools.partial(jax.jit, static_argnames=("interpret",))
def tier_sum_2d(x, w, *, interpret: bool = True):
    """Cross-tier accumulation ``sum_t w[t] * x[t]`` in one HBM pass.

    x: [T, M, 128k] stacked tier tiles (M % ROW_BLOCK == 0), w: [T] fp32
    normalized tier weights. The tier axis is the innermost grid dim, so
    each output row-block is revisited consecutively and accumulates in
    canonical (sorted-tier) order — the same order the jnp reference sums,
    keeping the two paths bit-comparable. Returns fp32 (callers cast)."""
    T, M, N = x.shape
    grid = (M // ROW_BLOCK, T)
    return pl.pallas_call(
        _tier_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # weights, prefetched whole
            pl.BlockSpec((1, ROW_BLOCK, N), lambda i, t: (t, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, N), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(w, jnp.float32), x)


def _sumsq_kernel(x_ref, out_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)
    block_sum = jnp.sum(x * x)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += block_sum


@functools.partial(jax.jit, static_argnames=("interpret",))
def sumsq_2d(x, *, interpret: bool = True):
    """Global sum of squares (for the clip norm), grid-carried accumulator."""
    M, N = x.shape
    grid = (M // ROW_BLOCK,)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_BLOCK, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, 0]

"""Jitted wrapper for the SSD scan kernel (model-layout convenience)."""
from __future__ import annotations

from repro.kernels.ssd_scan import kernel as K

_INTERPRET = True


def ssd_scan(x, dt, A, B, C, D=None, *, chunk: int = 128, interpret=None):
    interpret = _INTERPRET if interpret is None else interpret
    return K.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)

"""Oracle for the chunked SSD scan: re-exports the model's pure-jnp path."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked  # noqa: F401


def ssd_ref(x, dt, A, B, C, *, chunk: int = 128):
    y, h = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    return y, h

"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, nh, n_chunks) with the chunk axis innermost: TPU grids run
sequentially, so the inter-chunk recurrent state h [hd, st] lives in VMEM
scratch and is carried across chunk steps — the cross-chunk ``lax.scan`` of
the reference collapses into grid iteration (no HBM state round-trip).

Per chunk the kernel does the quadratic-in-chunk SSD math:
    s       = cumsum(dt * A)                       [cl]
    u       = x * dt                                [cl, hd]
    W       = tril(C B^T * exp(s_i - s_j))          [cl, cl]
    y       = W u + exp(s) * (C h_prev^T) + D x     [cl, hd]
    h_new   = exp(s_last) h_prev + sum_j exp(s_last - s_j) u_j (x) B_j
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                h_ref, *, cl, nc):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)             # [cl, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)           # [cl, 1]... stored [cl]
    A = a_ref[0]                                     # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)                # [cl, st]
    Cm = c_ref[0].astype(jnp.float32)                # [cl, st]
    D = d_ref[0]

    dt2 = dt.reshape(cl, 1)
    dA = dt2 * A                                     # [cl, 1]
    s = jnp.cumsum(dA, axis=0)                       # [cl, 1]
    u = x * dt2                                      # [cl, hd]

    CB = Cm @ Bm.T                                   # [cl, cl]
    Lm = jnp.exp(s - s.T)                            # exp(s_i - s_j)
    tri = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    W = jnp.where(tri, CB * Lm, 0.0)
    y = W @ u                                        # intra-chunk

    h_prev = h_ref[...]                              # [hd, st]
    y = y + jnp.exp(s) * (Cm @ h_prev.T)             # inter-chunk
    y = y + D * x

    decay_end = jnp.exp(s[cl - 1] - s)               # [cl, 1]
    h_chunk = (u * decay_end).T @ Bm                 # [hd, st]
    h_ref[...] = h_prev * jnp.exp(s[cl - 1, 0]) + h_chunk

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D=None, *, chunk: int = 128,
             interpret: bool = True):
    """x [Bt,S,nh,hd]; dt [Bt,S,nh]; A [nh]; B,C [Bt,S,st]; D [nh] or None.

    Returns (y [Bt,S,nh,hd], h_final [Bt,nh,hd,st]).
    """
    Bt, S, nh, hd = x.shape
    st = B.shape[-1]
    cl = min(chunk, S)
    assert S % cl == 0
    nc = S // cl
    if D is None:
        D = jnp.zeros((nh,), jnp.float32)
    xt = jnp.transpose(x, (0, 2, 1, 3))              # [Bt, nh, S, hd]
    dtt = jnp.transpose(dt, (0, 2, 1))               # [Bt, nh, S]
    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, cl=cl, nc=nc),
        grid=(Bt, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, cl, hd), lambda b, h_, c: (b, h_, c, 0)),
            pl.BlockSpec((1, 1, cl), lambda b, h_, c: (b, h_, c)),
            pl.BlockSpec((1,), lambda b, h_, c: (h_,)),
            pl.BlockSpec((1, cl, st), lambda b, h_, c: (b, c, 0)),
            pl.BlockSpec((1, cl, st), lambda b, h_, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h_, c: (h_,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cl, hd), lambda b, h_, c: (b, h_, c, 0)),
            pl.BlockSpec((1, 1, hd, st), lambda b, h_, c: (b, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, nh, S, hd), x.dtype),
            jax.ShapeDtypeStruct((Bt, nh, hd, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, st), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), B, C, D.astype(jnp.float32))
    return jnp.transpose(y, (0, 2, 1, 3)), h

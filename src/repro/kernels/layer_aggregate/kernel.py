"""Pallas TPU kernel for Eq. 8 layer-aligned aggregation.

The hot case during a 100-client round is a [N, L, F] client-stacked leaf
reduced over N per layer. Naive XLA materializes the weighted [N, L, F]
product; this kernel streams client slabs through VMEM and accumulates in a
fp32 block, one HBM read per element.

Grid: (L, F_blocks). Per step, the kernel sees one layer's client slab
c[:, l, fb] as an [N, FB] block, the weight column ww[:, l], and the server
row s[l, fb].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F_BLOCK = 512


def _agg_kernel(lam_ref, c_ref, ww_ref, s_ref, out_ref):
    c = c_ref[0].astype(jnp.float32)          # [N, FB]
    ww = ww_ref[...].astype(jnp.float32)       # [N, 1]
    s = s_ref[...].astype(jnp.float32)         # [1, FB]
    lam = lam_ref[0]
    num = jnp.sum(ww * c, axis=0, keepdims=True) + lam * s
    den = jnp.sum(ww) + lam
    out_ref[...] = (num / den).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def aggregate_3d(c, ww, s, lam, *, interpret: bool = True):
    """c [N, L, F] (F % F_BLOCK == 0), ww [N, L], s [L, F] -> [L, F]."""
    N, Lk, F = c.shape
    grid = (Lk, F // F_BLOCK)
    lam_arr = jnp.asarray([lam], jnp.float32)
    return pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, N, F_BLOCK), lambda l, f: (l, 0, f),
                         ),  # one layer's client slab (transposed view below)
            pl.BlockSpec((N, 1), lambda l, f: (0, l)),
            pl.BlockSpec((1, F_BLOCK), lambda l, f: (l, f)),
        ],
        out_specs=pl.BlockSpec((1, F_BLOCK), lambda l, f: (l, f)),
        out_shape=jax.ShapeDtypeStruct((Lk, F), s.dtype),
        interpret=interpret,
    )(lam_arr, jnp.swapaxes(c, 0, 1), ww, s)

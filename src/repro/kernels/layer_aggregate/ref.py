"""Oracle for layer-aligned weighted aggregation (paper Eq. 8).

    out[l, f] = (sum_n ww[n, l] * c[n, l, f] + lam * s[l, f])
                / (sum_n ww[n, l] + lam)

ww already folds the presence mask: ww[n, l] = w_n * (l < d_n).
"""
from __future__ import annotations

import jax.numpy as jnp


def aggregate(c, ww, s, lam):
    """c [N, L, F]; ww [N, L]; s [L, F] -> [L, F]."""
    num = jnp.einsum("nl,nlf->lf", ww.astype(jnp.float32),
                     c.astype(jnp.float32))
    den = jnp.sum(ww, axis=0).astype(jnp.float32)[:, None]
    out = (num + lam * s.astype(jnp.float32)) / (den + lam)
    return out.astype(s.dtype)

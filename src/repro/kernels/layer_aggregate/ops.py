"""Jitted wrapper: Eq. 8 aggregation for arbitrary client-stacked leaves."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.layer_aggregate import kernel as K

_INTERPRET = True


def aggregate_leaf(c, ww, s, lam, *, interpret=None):
    """c [N, L, ...]; ww [N, L]; s [L, ...] -> [L, ...]."""
    interpret = _INTERPRET if interpret is None else interpret
    N, Lk = c.shape[:2]
    F = 1
    for dim in c.shape[2:]:
        F *= dim
    c2 = c.reshape(N, Lk, F)
    s2 = s.reshape(Lk, F)
    pad = (-F) % K.F_BLOCK
    if pad:
        c2 = jnp.pad(c2, ((0, 0), (0, 0), (0, pad)))
        s2 = jnp.pad(s2, ((0, 0), (0, pad)))
    out = K.aggregate_3d(c2, ww, s2, lam, interpret=interpret)
    return out[:, :F].reshape(s.shape)

#!/usr/bin/env python3
"""Run fleetlint over the repo sources without installing anything.

The linter itself (``repro.analysis.fleetlint``) is stdlib-only, so this
wrapper just puts ``src/`` on the path and defaults the target to
``src/repro``. CI runs it before any heavyweight install:

    python tools/fleetlint.py              # lint src/repro
    python tools/fleetlint.py --list-rules
    python tools/fleetlint.py path/ --select FL002,FL004
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.fleetlint import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv or all(a.startswith("-") for a in argv):
        argv = [str(ROOT / "src" / "repro")] + argv
    sys.exit(main(argv))

#!/usr/bin/env python3
"""Doctest every fenced python example in README.md and docs/**.md.

``python -m doctest`` only takes explicit file arguments; this wrapper
globs the repo's markdown docs so a NEW doc with ``>>>`` examples is
covered the moment it lands (the CI ``docs`` job runs this plus
``tools/check_links.py``). Files without examples pass trivially —
plain ```` ```python ```` blocks without ``>>>`` prompts are prose, not
tests. Run from anywhere:

    PYTHONPATH=src python tools/doctest_docs.py
"""
from __future__ import annotations

import doctest
import sys
from pathlib import Path


def md_files(root: Path):
    yield root / "README.md"
    yield from sorted((root / "docs").glob("**/*.md"))


def main(root: Path = None) -> int:
    root = root or Path(__file__).resolve().parent.parent
    failed = tried = 0
    for md in md_files(root):
        res = doctest.testfile(str(md), module_relative=False)
        rel = md.relative_to(root)
        print(f"{rel}: {res.attempted} examples, {res.failed} failures")
        failed += res.failed
        tried += res.attempted
    if failed:
        print(f"FAILED: {failed}/{tried} doctest examples")
        return 1
    print(f"all {tried} doctest examples OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Fail on broken intra-repo links in README.md and docs/**.md.

Checks every markdown inline link whose target is a relative path
(external http(s)/mailto links and pure in-page anchors are skipped).
Targets are resolved relative to the file containing the link; an optional
``#fragment`` is stripped before the existence check. Run from anywhere:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(root: Path):
    yield from root.glob("*.md")
    yield from (root / "docs").glob("**/*.md")


def check(root: Path) -> int:
    broken = []
    for md in sorted(md_files(root)):
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                path = (md.parent / target.split("#", 1)[0]).resolve()
                if not path.exists():
                    broken.append(f"{md.relative_to(root)}:{n}: {target}")
    for b in broken:
        print(f"BROKEN LINK  {b}")
    if not broken:
        print(f"all intra-repo links OK in "
              f"{len(list(md_files(root)))} markdown files")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(check(Path(__file__).resolve().parent.parent))

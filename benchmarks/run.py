"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Paper mapping:
  table1_*    — Table I   (rounds / comm MB / modeled time to target)
  fig3_*      — Fig. 3    (accuracy per round)
  table2_*    — Table II  (power / energy / CO2 model)
  fig6_*      — Fig. 6    (TPGF fusion-rule ablation)
  table3_*    — Table III (server-gradient availability sweep)
  kernel_*    — Pallas kernel microbenches (CPU-interpret vs jnp oracle)
  roofline_*  — §Roofline summary per (arch x shape) from results/dryrun.jsonl
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

ROWS = []


def emit(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def bench_table1_fig3():
    """Rounds/comm/time to target accuracy, + accuracy curves (Fig. 3)."""
    from benchmarks.common import make_trainer, run_until, Timer
    target = 0.82   # above the rigid-split baseline's plateau (see
    # EXPERIMENTS.md §Paper-validation — the paper's rounds-to-target gap
    # appears at targets the baselines struggle to reach)
    results = {}
    for method in ("ssfl", "dfl", "sfl"):
        tr = make_trainer(method, n_clients=48, seed=0, local_steps=4,
                          lr=0.2, batch_size=16)
        with Timer() as t:
            curve, hit = run_until(tr, max_rounds=30, target=target)
        s = tr.accountant.summary()
        results[method] = (curve, hit, s)
        emit(f"table1_{method}_rounds_to_{int(target*100)}",
             t.dt * 1e6, hit if hit else f">{30}")
        emit(f"table1_{method}_comm_mb", t.dt * 1e6, round(s["comm_mb"], 1))
        emit(f"table1_{method}_modeled_time_s", t.dt * 1e6, s["time_s"])
        emit(f"table2_{method}_avg_power_w", t.dt * 1e6, s["avg_power_w"])
        emit(f"table2_{method}_co2_g", t.dt * 1e6, s["co2_g"])
        final_acc = curve[-1][1]
        emit(f"table2_{method}_power_per_acc",
             t.dt * 1e6,
             round(s["avg_power_w"] / max(final_acc * 100, 1e-6), 3))
        for r, acc in curve:
            emit(f"fig3_{method}_round{r:02d}_acc", 0.0, round(acc, 4))
    if results["ssfl"][1] and results["sfl"][1]:
        emit("table1_speedup_rounds_ssfl_vs_sfl", 0.0,
             round(results["sfl"][1] / results["ssfl"][1], 2))
        emit("table1_comm_reduction_ssfl_vs_sfl", 0.0,
             round(results["sfl"][2]["comm_mb"]
                   / max(results["ssfl"][2]["comm_mb"], 1e-9), 2))
    return results


def bench_fig6_ablation():
    from benchmarks.common import make_trainer, run_until, sim_config
    for variant in ("full", "no_loss", "no_depth", "equal"):
        cfg = sim_config(tpgf_variant=variant)
        tr = make_trainer("ssfl", cfg=cfg, n_clients=12, seed=1, noise=0.85,
                          availability=0.8)
        curve, _ = run_until(tr, max_rounds=20, eval_every=4)
        emit(f"fig6_tpgf_{variant}_final_acc", 0.0, round(curve[-1][1], 4))


def bench_scenario_sampling():
    """Engine-native scenario knob: per-round client sampling (the first
    knob the strategy-registry engine adds over the seed trainer)."""
    from benchmarks.common import make_engine
    for frac in (1.0, 0.5):
        eng = make_engine("ssfl", n_clients=8, seed=5, sample_frac=frac,
                          local_steps=2, batch_size=16)
        for _ in range(3):
            rec = eng.run_round()
        emit(f"scenario_sample_frac_{int(frac*100):03d}_comm_mb", 0.0,
             round(rec["comm_mb"], 2))
        emit(f"scenario_sample_frac_{int(frac*100):03d}_loss", 0.0,
             round(rec["loss"], 4))


def bench_table3_availability():
    from benchmarks.common import make_trainer, run_until
    for frac in (1.0, 0.7, 0.5, 0.2, 0.0):
        tr = make_trainer("ssfl", availability=frac, n_clients=12, seed=2,
                          noise=0.45)
        curve, _ = run_until(tr, max_rounds=24, eval_every=4)
        emit(f"table3_avail_{int(frac*100):03d}_final_acc", 0.0,
             round(curve[-1][1], 4))


def bench_engine():
    """Device-resident round-path throughput (PR 3 tentpole): rounds/sec
    and compiles-per-5-round-run at N in {8, 32, 64} clients under
    per-round cohort churn (sample_frac=0.8), fused execution (bucket
    ladder + scanned local steps + on-device batch gather) vs the
    ``bucketing="exact"`` reference that re-specializes per distinct cohort
    size like the pre-refactor engine did. Emits ``engine_*`` rows and
    writes BENCH_engine.json so the perf trajectory is tracked from this
    PR onward. (The true pre-refactor path also staged batches through the
    host each step, so the reference is a conservative floor — measured
    pre-refactor hasfl@64 was 0.099 rounds/s on the same harness.)"""
    import time
    from benchmarks.common import sim_config
    from repro.federated import Engine
    from repro.federated import bucketing as BK

    # test-scale model (matches the parity/bucketing test config): the
    # engine bench measures ROUND-PATH overhead — dispatch, recompiles,
    # host syncs — which the full sim_config model would drown in matmul
    # time on 1 CPU core
    cfg = sim_config(n_layers=4, d_model=48, head_dim=12, d_ff=96,
                     n_classes=6)
    results = {}
    for method in ("ssfl", "hasfl"):
        for n in (8, 32, 64):
            row = {}
            for mode, bucketing in (("reference", "exact"),
                                    ("fused", "ladder")):
                eng = Engine(cfg, n, method, seed=0, lr=0.2, local_steps=2,
                             batch_size=8, sample_frac=0.8,
                             bucketing=bucketing)
                eng.run_round()   # warm the round path
                c0 = BK.kernel_compiles()
                t0 = time.perf_counter()
                for _ in range(5):
                    eng.run_round()
                dt = time.perf_counter() - t0
                row[mode] = {"rounds_per_s": round(5 / dt, 3),
                             "compiles_5rounds": BK.kernel_compiles() - c0}
                emit(f"engine_{method}_n{n:02d}_{mode}_rounds_per_s",
                     dt / 5 * 1e6, row[mode]["rounds_per_s"])
                emit(f"engine_{method}_n{n:02d}_{mode}_compiles5", 0.0,
                     row[mode]["compiles_5rounds"])
            row["speedup_fused_vs_reference"] = round(
                row["fused"]["rounds_per_s"]
                / max(row["reference"]["rounds_per_s"], 1e-9), 2)
            emit(f"engine_{method}_n{n:02d}_speedup", 0.0,
                 row["speedup_fused_vs_reference"])
            results[f"{method}_n{n}"] = row
    payload = {
        "setting": "sim_config reduced to n_layers=4/d_model=48/d_ff=96, "
                   "seed=0, lr=0.2, local_steps=2, batch_size=8, "
                   "sample_frac=0.8, 5 timed rounds after 1 warmup",
        "note": "reference = bucketing='exact' (one compile per distinct "
                "cohort size, like the pre-refactor engine); fused = "
                "default bucket ladder. Both use scanned steps + device "
                "batch gather, so the ratio under-states the win over the "
                "true pre-refactor host-staged path.",
        "results": results,
    }
    path = os.path.join(ROOT, "BENCH_engine.json")
    if os.path.exists(path):   # keep bench_engine_sharded's section
        prev = json.load(open(path))
        if "sharded_8dev" in prev:
            payload["sharded_8dev"] = prev["sharded_8dev"]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return results


def bench_engine_sharded():
    """Multi-device fleet execution (PR 4 tentpole): rounds/sec of the
    shard_map'd bucket kernels vs the replicated path at N in {32, 64},
    measured on a forced 8-device host in a subprocess (the device-count
    flag must never touch this process — same discipline as the
    tier-1 conftest guard). Emits ``engine_sharded_*`` rows and merges a
    ``sharded_8dev`` section into BENCH_engine.json."""
    import subprocess

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks",
                                      "sharded_worker.py")],
        capture_output=True, text=True, env=env, timeout=3600)
    if r.returncode != 0:
        # keep the row stream one-record-per-line: full stderr to our own
        # stderr, a flattened tail in the derived field
        print(r.stderr, file=sys.stderr)
        emit("engine_sharded_worker_failed", 0.0,
             r.stderr[-200:].replace("\n", " ").replace(",", ";"))
        return None
    results = json.loads(r.stdout.strip().splitlines()[-1])
    for name, row in results.items():
        for mode in ("replicated", "sharded"):
            emit(f"engine_sharded_{name}_{mode}_rounds_per_s",
                 1e6 / max(row[mode]["rounds_per_s"], 1e-9),
                 row[mode]["rounds_per_s"])
        emit(f"engine_sharded_{name}_ratio", 0.0,
             row["ratio_sharded_vs_replicated"])
        if "kernel_ratio_sharded_vs_replicated" in row:
            emit(f"engine_sharded_{name}_kernel_ratio", 0.0,
                 row["kernel_ratio_sharded_vs_replicated"])
    path = os.path.join(ROOT, "BENCH_engine.json")
    payload = json.load(open(path)) if os.path.exists(path) else {}
    payload["sharded_8dev"] = {
        "setting": "same reduced sim_config as `results`, best of 3 "
                   "passes x 3 timed rounds after 1 warmup, XLA_FLAGS="
                   "--xla_force_host_platform_device_count=8, fleet mesh "
                   "= 1-D ('data',) over all 8 forced devices",
        "note": "replicated = same 8-device process, kernels compute on "
                "one device; sharded = shard_map over the fleet axis "
                "(bucket slots split 8 ways, psum'd pooled means). Forced "
                "host devices SHARE the physical cores, so the ratio "
                "measures partition/dispatch overhead, not multi-chip "
                "speedup: the single-device baseline already gets full "
                "XLA intra-op parallelism over the slot-batched matmuls, "
                "while the sharded path pays 8 serialized executables + "
                "collectives + eager multi-device glue per round. "
                "kernel_s_per_round / kernel_ratio isolate the "
                "cohort-kernel phase from that glue; both the end-to-end "
                "and kernel ratios swing with container CPU contention "
                "(passes are interleaved so both modes see the same "
                "load). On real multi-chip hosts the sharded path is the "
                "one that scales with device count.",
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return results


def bench_async():
    """Buffered-async aggregation study (PR 5 tentpole): synchronous
    staleness-weighted folding (``unstable``, Wei et al.) vs FedBuff-style
    buffered folding (``async_buffered``) under gamma x Markov operating
    points, with the FedOpt server-optimizer family on the buffered side.
    The operating points follow Han et al.'s heterogeneous-data convergence
    analysis: what matters is the *stationary participation fraction* and
    the *outage correlation length*, so the sweep pins one flaky-but-mostly-
    up chain and one mostly-down chain rather than more gamma points.
    Emits ``async_*`` rows and writes BENCH_async.json + BENCH_async.md
    (the markdown comparison table). Schema in docs/benchmarks.md."""
    import time

    import numpy as np

    from benchmarks.common import sim_config
    from repro.federated import Engine
    from repro.federated.strategies.async_buffered import BufferedAsync
    from repro.federated.strategies.unstable import UnstableParticipation

    cfg = sim_config(n_layers=4, d_model=48, head_dim=12, d_ff=96,
                     n_classes=6)
    GAMMAS = (0.5, 2.0)
    # Markov operating points: stationary on-fraction 2/3 with ~5-round
    # mean outages (flaky) vs 1/3 with ~7-round outages (mostly_down)
    MARKOV = (("flaky", dict(p_up=0.4, p_down=0.2, straggle_p=0.1)),
              ("mostly_down", dict(p_up=0.15, p_down=0.3, straggle_p=0.1)))
    SERVER_OPTS = (("sgd", 1.0), ("fedadam", 0.03), ("fedyogi", 0.03))
    N_CLIENTS, ROUNDS = 8, 8

    def run_one(tag, strat):
        eng = Engine(cfg, N_CLIENTS, strat, seed=0, lr=0.2, local_steps=2,
                     batch_size=8)
        t0 = time.perf_counter()
        losses = [eng.run_round()["loss"] for _ in range(ROUNDS)]
        dt = time.perf_counter() - t0
        finite = [l for l in losses if l == l]   # drop empty-round NaNs
        # "flushes" = global updates actually applied: buffer flushes for
        # async_buffered; for unstable, the rounds that folded (a round
        # with zero participants leaves the globals untouched)
        row = {"final_acc": round(eng.evaluate(max_batches=4), 4),
               "mean_loss": round(float(np.mean(finite)), 4) if finite
               else None,
               "rounds_per_s": round(ROUNDS / dt, 3),
               "flushes": getattr(strat, "flushes", len(finite))}
        emit(f"async_{tag}_final_acc", dt / ROUNDS * 1e6, row["final_acc"])
        emit(f"async_{tag}_flushes", 0.0, row["flushes"])
        return row

    results = {}
    for mk_name, mk in MARKOV:
        for gamma in GAMMAS:
            key = f"{mk_name}_gamma{gamma}"
            grp = {}
            grp["unstable"] = run_one(
                f"{key}_unstable",
                UnstableParticipation(gamma=gamma, **mk))
            for so, slr in SERVER_OPTS:
                grp[f"async_buffered_{so}"] = run_one(
                    f"{key}_buffered_{so}",
                    BufferedAsync(capacity=4, gamma=gamma, server_opt=so,
                                  server_lr=slr, **mk))
            results[key] = grp
    payload = {
        "setting": "sim_config reduced to n_layers=4/d_model=48/d_ff=96, "
                   f"n_clients={N_CLIENTS}, seed=0, lr=0.2, local_steps=2, "
                   f"batch_size=8, {ROUNDS} rounds, eval on 4x64 test "
                   "samples; async_buffered: capacity=4, policy='count', "
                   "server_lr 1.0 (sgd) / 0.03 (fedadam, fedyogi)",
        "note": "unstable folds every round (staleness-discounted Eq.6 "
                "weights); async_buffered defers cohort deltas into the "
                "capacity-4 server buffer and only moves the globals on "
                "flush, through the named server optimizer. gamma drives "
                "both the per-client discount and the flush-time entry "
                "discount. Markov points: flaky = pi_on 2/3, mean outage "
                "5 rounds; mostly_down = pi_on 1/3, mean outage ~6.7 "
                "rounds (plus 10% deadline stragglers each).",
        "results": results,
    }
    with open(os.path.join(ROOT, "BENCH_async.json"), "w") as f:
        json.dump(payload, f, indent=1)
    _write_async_md(results, payload)
    return results


def _write_async_md(results, payload):
    """BENCH_async.md: one markdown table per Markov operating point,
    strategies as rows, gamma sweep as column groups."""
    variants = ("unstable", "async_buffered_sgd", "async_buffered_fedadam",
                "async_buffered_fedyogi")
    gammas, points = [], []
    for key in results:
        mk, g = key.rsplit("_gamma", 1)
        if mk not in points:
            points.append(mk)
        if g not in gammas:
            gammas.append(g)
    lines = ["# Buffered-async aggregation study (`bench_async`)", "",
             payload["setting"], "", payload["note"], ""]
    for mk in points:
        lines += [f"## Markov operating point: `{mk}`", ""]
        head = "| strategy | " + " | ".join(
            f"acc (γ={g}) | loss (γ={g}) | flushes (γ={g})" for g in gammas
        ) + " |"
        lines += [head,
                  "|" + "---|" * (1 + 3 * len(gammas))]
        for v in variants:
            cells = []
            for g in gammas:
                row = results[f"{mk}_gamma{g}"][v]
                cells += [f"{row['final_acc']:.3f}",
                          f"{row['mean_loss']}", f"{row['flushes']}"]
            lines.append("| `" + v + "` | " + " | ".join(cells) + " |")
        lines.append("")
    with open(os.path.join(ROOT, "BENCH_async.md"), "w") as f:
        f.write("\n".join(lines))


def bench_supernet(rounds: int = 6):
    """Elastic width-sliceable supernet study (PR 7 tentpole): final
    accuracy, accuracy-per-byte AND convergence curves across width tiers
    x strategies. Each (strategy, tier) cell trains ``rounds`` rounds with
    the fleet pinned to that width tier (single-tier ladder); the
    ``ladder`` cell lets ``core.allocation`` map client memory budgets
    onto the (0.5, 1.0) ladder, so narrow devices download the sliced
    prefix while the wide ones keep the full supernet. ``acc_per_byte`` =
    final accuracy / cumulative fleet communication — the paper's
    accuracy-per-resource lens with bytes as the resource. The per-round
    eval trace becomes a convergence curve per cell: rounds-to-target and
    bytes-to-target (Table-1's "resource to reach X%" lens). A second
    sweep (PR 10 tentpole) runs mixed-tier cohorts at N in {64, 256}
    under ``cross_tier="fused"`` (one TPGF update per cohort) vs
    ``"chained"`` (per-tier sequential folds) and records the same
    convergence lens for each — the ``cross_tier`` section of the JSON.
    Emits ``supernet_*`` rows and writes BENCH_supernet.json (schema in
    docs/benchmarks.md)."""
    import numpy as np

    from benchmarks.common import sim_config
    from repro.core import supernet as SN
    from repro.federated import Engine

    cfg = sim_config(n_layers=4, d_model=48, head_dim=12, d_ff=96,
                     n_classes=6)
    TIERS = (0.5, 1.0)
    TARGETS = (0.2, 0.3)   # accuracy thresholds for the convergence lens
    results = {}
    convergence = {}
    for method in ("ssfl", "hasfl"):
        for tier in TIERS + ("ladder",):
            ladder = TIERS if tier == "ladder" else (tier,)
            eng = Engine(cfg, 8, method, seed=0, lr=0.2, local_steps=2,
                         batch_size=8, width_tiers=ladder)
            curve = []   # [round, eval_acc, cumulative comm_mb]
            for r in range(rounds):
                eng.run_round()
                curve.append([r + 1,
                              round(eng.evaluate(max_batches=4), 4),
                              round(eng.accountant.summary()["comm_mb"],
                                    3)])
            acc = curve[-1][1]
            s = eng.accountant.summary()
            widths = np.asarray(eng.state.fleet.widths, float)
            dl = float(np.mean(
                [SN.client_param_bytes(cfg, eng.state.params, int(d),
                                       float(w))
                 for d, w in zip(eng.state.fleet.depths, widths)]))
            comm_bytes = max(s["comm_mb"] * 2**20, 1e-9)
            key = f"{method}_w{tier}"
            row = {"strategy": method,
                   "width_tier": tier if tier == "ladder" else float(tier),
                   "mean_width": round(float(widths.mean()), 3),
                   "final_acc": round(acc, 4),
                   "comm_mb": s["comm_mb"],
                   "mean_client_download_bytes": int(dl),
                   "acc_per_byte": float(f"{acc / comm_bytes:.3e}"),
                   "acc_per_gb": round(acc * 2**30 / comm_bytes, 3)}
            results[key] = row
            targets = {}
            for tgt in TARGETS:
                hit = next((p for p in curve if p[1] >= tgt), None)
                targets[f"{tgt:g}"] = {
                    "rounds_to_target": None if hit is None else hit[0],
                    "mb_to_target": None if hit is None else hit[2]}
            convergence[key] = {"strategy": method,
                                "width_tier": row["width_tier"],
                                "curve": curve, "targets": targets}
            emit(f"supernet_{key}_final_acc", 0.0, row["final_acc"])
            emit(f"supernet_{key}_comm_mb", 0.0, row["comm_mb"])
            emit(f"supernet_{key}_acc_per_gb", 0.0, row["acc_per_gb"])
            r2t = targets[f"{TARGETS[0]:g}"]["rounds_to_target"]
            emit(f"supernet_{key}_rounds_to_{TARGETS[0]:g}", 0.0,
                 "n/a" if r2t is None else r2t)
    # ---- cross-tier fusion sweep: mixed-width cohorts, fused vs chained.
    # Same model/seed/ladder as the cells above; the knob is the only
    # difference, so the convergence gap is attributable to the fusion law.
    COHORTS = (64, 256)
    cross_cells = {}
    for n in COHORTS:
        for mode in ("fused", "chained"):
            eng = Engine(cfg, n, "ssfl", seed=0, lr=0.2, local_steps=2,
                         batch_size=8, width_tiers=TIERS, cross_tier=mode)
            widths = np.asarray(eng.state.fleet.widths, float)
            curve = []
            for r in range(rounds):
                eng.run_round()
                curve.append([r + 1,
                              round(eng.evaluate(max_batches=4), 4),
                              round(eng.accountant.summary()["comm_mb"],
                                    3)])
            targets = {}
            for tgt in TARGETS:
                hit = next((p for p in curve if p[1] >= tgt), None)
                targets[f"{tgt:g}"] = {
                    "rounds_to_target": None if hit is None else hit[0],
                    "mb_to_target": None if hit is None else hit[2]}
            key = f"ssfl_n{n}_{mode}"
            cross_cells[key] = {
                "strategy": "ssfl", "n_clients": n, "cross_tier": mode,
                "mean_width": round(float(widths.mean()), 3),
                "final_acc": curve[-1][1],
                "comm_mb": eng.accountant.summary()["comm_mb"],
                "curve": curve, "targets": targets}
            emit(f"supernet_{key}_final_acc", 0.0, curve[-1][1])
            r2t = targets[f"{TARGETS[0]:g}"]["rounds_to_target"]
            emit(f"supernet_{key}_rounds_to_{TARGETS[0]:g}", 0.0,
                 "n/a" if r2t is None else r2t)
    payload = {
        "setting": "sim_config reduced to n_layers=4/d_model=48/d_ff=96, "
                   f"n_clients=8, seed=0, lr=0.2, local_steps=2, "
                   f"batch_size=8, {rounds} rounds, eval on 4x64 test "
                   "samples; width tiers pinned via single-tier ladders, "
                   "'ladder' = allocation over (0.5, 1.0)",
        "note": "acc_per_byte = final_acc / cumulative fleet comm bytes "
                "(acc_per_gb is the same number scaled by 2^30 for "
                "readability). Width slices only the client prefix "
                "download — the smashed stream stays full d_model — so "
                "the byte saving grows with split depth and local steps.",
        "results": results,
        "convergence": {
            "note": "curve = [round, eval_acc, cumulative comm_mb] per "
                    "round; targets map an accuracy threshold to the "
                    "first round (and the fleet bytes spent by then) "
                    "that reaches it — null when never reached within "
                    "the budget.",
            "targets": [float(t) for t in TARGETS],
            "cells": convergence,
        },
        "cross_tier": {
            "note": "mixed-width (0.5, 1.0) cohorts at fleet size "
                    "n_clients: cross_tier='fused' lifts each tier's TPGF "
                    "output to full width and fuses ONE update with "
                    "per-coordinate denominators; 'chained' folds the "
                    "tiers sequentially (per-tier aggregation). curve / "
                    "targets use the same convergence lens as above.",
            "cohorts": list(COHORTS),
            "targets": [float(t) for t in TARGETS],
            "cells": cross_cells,
        },
    }
    with open(os.path.join(ROOT, "BENCH_supernet.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return results


def bench_kernels():
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import time_call
    rng = np.random.default_rng(0)

    from repro.kernels.tpgf_fusion import ops as FO, ref as FR
    a = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    us_ref = time_call(lambda: FR.fuse(a, b, 0.3, 0.9))
    got = FO.fuse_leaf(a, b, 0.3, 0.9)
    err = float(jnp.max(jnp.abs(got - FR.fuse(a, b, 0.3, 0.9))))
    emit("kernel_tpgf_fusion_ref_jnp", us_ref, f"interp_maxerr={err:.1e}")

    from repro.kernels.layer_aggregate import ops as AO, ref as AR
    c = jnp.asarray(rng.normal(size=(16, 6, 4096)), jnp.float32)
    ww = jnp.asarray(rng.uniform(size=(16, 6)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(6, 4096)), jnp.float32)
    us_ref = time_call(lambda: AR.aggregate(c, ww, s, 0.01))
    err = float(jnp.max(jnp.abs(AO.aggregate_leaf(c, ww, s, 0.01)
                                - AR.aggregate(c, ww, s, 0.01))))
    emit("kernel_layer_aggregate_ref_jnp", us_ref, f"interp_maxerr={err:.1e}")

    from repro.kernels.flash_attention import ops as O, ref as R
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    us_ref = time_call(lambda: R.flash_attention_ref(q, k, v, causal=True))
    err = float(jnp.max(jnp.abs(O.flash_attention(q, k, v, causal=True)
                                - R.flash_attention_ref(q, k, v, causal=True))))
    emit("kernel_flash_attention_ref_jnp", us_ref, f"interp_maxerr={err:.1e}")

    from repro.kernels.ssd_scan import ops as SO, ref as SR
    x = jnp.asarray(rng.normal(size=(1, 512, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (1, 512, 4)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (4,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(1, 512, 16)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(1, 512, 16)), jnp.float32)
    us_ref = time_call(lambda: SR.ssd_ref(x, dt, A, B, C, chunk=128)[0])
    yk, _ = SO.ssd_scan(x, dt, A, B, C, chunk=128)
    yr, _ = SR.ssd_ref(x, dt, A, B, C, chunk=128)
    err = float(jnp.max(jnp.abs(yk - yr)))
    emit("kernel_ssd_scan_ref_jnp", us_ref, f"interp_maxerr={err:.1e}")


def bench_roofline():
    path = os.path.join(ROOT, "results", "dryrun.jsonl")
    if not os.path.exists(path):
        emit("roofline_missing", 0.0, "run python -m repro.launch.dryrun")
        return
    best = {}
    for line in open(path):
        r = json.loads(line)
        if "dominant" not in r:
            continue
        best[(r["arch"], r["shape"], r["mesh"])] = r
    for (arch, shape, mesh), r in sorted(best.items()):
        if mesh != "16x16":
            continue
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline_{arch}_{shape}", t * 1e6,
             f"dom={r['dominant']};useful={r['useful_flops_ratio']:.2f}")


ALL_BENCHES = ("bench_table1_fig3", "bench_fig6_ablation",
               "bench_table3_availability", "bench_scenario_sampling",
               "bench_engine", "bench_engine_sharded", "bench_async",
               "bench_supernet", "bench_kernels", "bench_roofline")


def main(argv=None) -> None:
    """Run every bench, or just the ones named on the command line
    (``python benchmarks/run.py bench_engine bench_engine_sharded``).
    ``--rounds N`` shortens the benches that take a round budget
    (``bench_supernet``) — the CI smoke runs ``bench_supernet --rounds 2``."""
    import inspect
    names = list(argv if argv is not None else sys.argv[1:])
    rounds = None
    if "--rounds" in names:
        i = names.index("--rounds")
        rounds = int(names[i + 1])
        del names[i:i + 2]
    names = names or list(ALL_BENCHES)
    unknown = [n for n in names if n not in ALL_BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; "
                         f"available: {list(ALL_BENCHES)}")
    for name in names:
        fn = globals()[name]
        kw = {"rounds": rounds} if rounds is not None and \
            "rounds" in inspect.signature(fn).parameters else {}
        fn(**kw)
    print(f"# {len(ROWS)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Shared benchmark config: one reduced-scale federated setting.

Paper scale (ViT-16, CIFAR, 50-100 clients, A100s) is scaled to this
container (1 CPU core): ViT family reduced to 6 layers / d_model 64 on a
16x16 synthetic-CIFAR with the SAME protocol (Dirichlet alpha=0.5 non-IID,
mem~U[2,16] GB, lat~U[20,200] ms heterogeneity, Eq.1 allocation). Trends,
not absolute numbers, are the reproduction target (EXPERIMENTS.md).
"""
from __future__ import annotations

import time

from repro.configs import base


def sim_config(**kw):
    cfg = base.get_reduced("vit16_cifar").replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, image_size=16, n_classes=10)
    return cfg.replace(**kw) if kw else cfg


def make_trainer(method: str, *, n_clients: int = 16, seed: int = 0,
                 availability: float = 1.0, cfg=None, alpha: float = 0.2,
                 lr: float = 0.25, local_steps: int = 3,
                 batch_size: int = 32, noise: float = 0.7):
    from repro.federated.round import FederatedTrainer
    return FederatedTrainer(cfg or sim_config(), n_clients, method,
                            seed=seed, lr=lr, local_steps=local_steps,
                            batch_size=batch_size, availability=availability,
                            alpha=alpha, noise=noise)


def make_engine(strategy: str, *, n_clients: int = 16, seed: int = 0,
                availability: float = 1.0, sample_frac: float = 1.0,
                optimizer="sgd", cfg=None, alpha: float = 0.2,
                lr: float = 0.25, local_steps: int = 3,
                batch_size: int = 32, noise: float = 0.7):
    """Engine-native variant of ``make_trainer`` exposing the scenario
    knobs the old trainer API could not (sample_frac, optimizer)."""
    from repro.federated import Engine
    return Engine(cfg or sim_config(), n_clients, strategy,
                  seed=seed, lr=lr, local_steps=local_steps,
                  batch_size=batch_size, availability=availability,
                  sample_frac=sample_frac, optimizer=optimizer,
                  alpha=alpha, noise=noise)


def run_until(trainer, *, max_rounds: int, target: float = None,
              eval_every: int = 1):
    """Returns (history of (round, acc), rounds_to_target or None)."""
    curve = []
    hit = None
    for r in range(max_rounds):
        trainer.run_round()
        if (r + 1) % eval_every == 0:
            acc = trainer.evaluate()
            curve.append((r + 1, acc))
            if target is not None and hit is None and acc >= target:
                hit = r + 1
                break
    return curve, hit


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def time_call(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # warm up / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(r)
    except Exception:
        pass
    return (time.perf_counter() - t0) / repeat * 1e6  # us

"""Worker for ``bench_engine_sharded`` — run on a FORCED 8-device host.

The parent (``benchmarks/run.py``) spawns this with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the flag never
touches the benchmark process itself. Measures rounds/sec of the
shard-mapped fleet execution against the replicated path on the SAME
8-device process (identical model, seed, churn), prints one JSON object on
the last line.
"""
from __future__ import annotations

import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)


def main():
    import jax
    import numpy as np
    from benchmarks.common import sim_config
    from repro.federated import Engine
    from repro.federated import bucketing as BK
    from repro.launch.mesh import make_fleet_mesh

    assert len(jax.devices()) >= 8, len(jax.devices())
    mesh = make_fleet_mesh(8)
    cfg = sim_config(n_layers=4, d_model=48, head_dim=12, d_ff=96,
                     n_classes=6)

    def kernel_phase_time(eng, rounds=3):
        """Per-round wall seconds spent inside cohort_step (blocked on its
        device outputs) — isolates the sharded KERNEL win from the eager
        round-glue overhead forced-host devices exaggerate. Instrumented
        separately from the throughput passes: blocking breaks dispatch
        pipelining."""
        import jax
        strat = eng.strategy
        orig = type(strat).cohort_step
        acc = [0.0]

        def timed(self, *a, **k):
            t0 = time.perf_counter()
            r = orig(self, *a, **k)
            jax.block_until_ready(
                r.losses if r.losses is not None else r.payload)
            acc[0] += time.perf_counter() - t0
            return r

        strat.cohort_step = timed.__get__(strat)
        for _ in range(rounds):
            eng.run_round()
        strat.cohort_step = orig.__get__(strat)
        return round(acc[0] / rounds, 3)

    results = {}
    for method in ("ssfl", "hasfl"):
        for n in (32, 64):
            # warm both round paths, then INTERLEAVE timed passes so both
            # modes see the same neighbor load (this container's CPU share
            # swings ~2x between runs); best-of-passes measures the code,
            # not the neighbors
            engines = {mode: Engine(cfg, n, method, seed=0, lr=0.2,
                                    local_steps=2, batch_size=8,
                                    sample_frac=0.8, mesh=m)
                       for mode, m in (("replicated", None),
                                       ("sharded", mesh))}
            for eng in engines.values():
                eng.run_round()
            c0 = BK.kernel_compiles()
            best = {mode: 0.0 for mode in engines}
            for _ in range(3):
                for mode, eng in engines.items():
                    t0 = time.perf_counter()
                    for _ in range(3):
                        eng.run_round()
                    best[mode] = max(best[mode],
                                     3 / (time.perf_counter() - t0))
            row = {mode: {"rounds_per_s": round(best[mode], 3),
                          "kernel_s_per_round":
                              kernel_phase_time(engines[mode])}
                   for mode in engines}
            row["compiles_timed_rounds"] = BK.kernel_compiles() - c0
            row["ratio_sharded_vs_replicated"] = round(
                best["sharded"] / max(best["replicated"], 1e-9), 2)
            row["kernel_ratio_sharded_vs_replicated"] = round(
                row["replicated"]["kernel_s_per_round"]
                / max(row["sharded"]["kernel_s_per_round"], 1e-9), 2)
            results[f"{method}_n{n}"] = row
    print(json.dumps(results))


if __name__ == "__main__":
    main()

"""Elastic width-sliceable supernet — the slice-parity contract (PR 7).

Pins the four width views of ``repro.core.supernet`` and their algebra:

  * slice-then-forward == forward-then-mask (allclose: the two traces
    reduce matmuls in different orders, so bit-exactness is NOT the
    contract here — everything structural is);
  * ``widen(slice(t)) == mask(t)`` and the scatter identity
    ``scatter(t, slice(t)) == t``, both BIT-exact (pure copy/zero ops);
  * scatter-back touches ONLY the kept coordinates;
  * width=1.0 is the identity everywhere (the legacy bit-exact path);
  * heterogeneous-width training state survives save/restore
    bit-identically (widths ride the engine stream metadata).

Property tests need hypothesis (dev extras); they skip clean without it,
the deterministic classes below always run.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core import supernet as SN
from repro.federated import Engine
from repro.models import model as M


def _cfg(**kw):
    d = dict(n_layers=3, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
             d_ff=64, image_size=16, n_classes=6)
    d.update(kw)
    return base.get_reduced("vit16_cifar").replace(**d)


CFG = _cfg()
WIDTHS = (0.25, 0.5, 0.75)


def _params(seed: int):
    return M.init_params(CFG, jax.random.PRNGKey(seed))


def _batch(seed: int, n: int = 2):
    rng = np.random.default_rng(seed)
    return {"images": jnp.asarray(
                rng.normal(size=(n, CFG.image_size, CFG.image_size, 3)),
                jnp.float32),
            "label": jnp.asarray(rng.integers(0, CFG.n_classes, n),
                                 jnp.int32)}


# one compiled forward per width cfg; cfg is frozen/hashable == static key
_fwd = jax.jit(M.client_apply, static_argnums=0)


def _plan_masks(cfg, tree, width):
    """(path, leaf, kept?) triples: kept is the bool prefix mask for plan
    leaves, None for full-width leaves."""
    plan = SN.width_plan(cfg, width)
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = SN._leaf_name(path)
        if name in plan:
            ax, keep = plan[name]
            axis = leaf.ndim + ax
            kept = np.arange(leaf.shape[axis]) < keep
            yield path, leaf, (axis, kept)
        else:
            yield path, leaf, None


def _engine(method, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 2)
    kw.setdefault("batch_size", 4)
    cfg = kw.pop("cfg", None) or _cfg()
    return Engine(cfg, kw.pop("n_clients", 6), method, **kw)


# ------------------------------------------------------------- properties
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    S = settings(max_examples=200, deadline=None)

    class TestSliceParityProperties:
        """The ISSUE's three properties, >=200 random examples each."""

        @S
        @given(width=st.sampled_from(WIDTHS),
               d=st.integers(1, CFG.split_stack_len - 1),
               pseed=st.integers(0, 3), bseed=st.integers(0, 10**6))
        def test_slice_forward_equals_mask_forward(self, width, d, pseed,
                                                   bseed):
            """Forwarding the width-w SLICE equals forwarding the full
            client view with the pruned coordinates ZEROED: pruned head /
            hidden outputs are killed by the zeroed wo / w_down rows, so
            the two computations agree up to matmul reduction order."""
            params, batch = _params(pseed), _batch(bseed)
            full_c = SN.split_params(CFG, params, d)[0]
            sliced_c = SN.split_params(CFG, params, d, width)[0]
            z_sliced, _ = _fwd(SN.width_cfg(CFG, width), sliced_c, batch)
            z_masked, _ = _fwd(CFG, SN.mask_width(CFG, full_c, width),
                               batch)
            np.testing.assert_allclose(np.asarray(z_sliced),
                                       np.asarray(z_masked),
                                       rtol=1e-4, atol=1e-4)

        @S
        @given(width=st.sampled_from(WIDTHS),
               d=st.integers(1, CFG.split_stack_len - 1),
               pseed=st.integers(0, 3))
        def test_roundtrip_bit_exact(self, width, d, pseed):
            """widen(slice(t)) == mask(t) and scatter(t, slice(t)) == t,
            bit for bit; and depth split/merge round-trips the whole
            supernet bit-exact with the width axis in play."""
            params = _params(pseed)
            client = SN.split_params(CFG, params, d)[0]
            sliced = SN.slice_width(CFG, client, width)
            widened = SN.widen_width(CFG, sliced, width)
            masked = SN.mask_width(CFG, client, width)
            for a, b in zip(jax.tree.leaves(widened),
                            jax.tree.leaves(masked)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            rt = SN.scatter_width(CFG, client, sliced, width)
            for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(client)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            cw, server, local = SN.split_params(CFG, params, d, width)
            full_c = SN.scatter_width(CFG, client, cw, width)
            merged = SN.merge_params(CFG, full_c, server, local)
            assert set(merged) == set(params)
            for a, b in zip(jax.tree.leaves(merged),
                            jax.tree.leaves(params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        @S
        @given(width=st.sampled_from(WIDTHS),
               d=st.integers(1, CFG.split_stack_len - 1),
               sa=st.integers(0, 10**6), sb=st.integers(0, 10**6))
        def test_scatter_touches_only_kept_coords(self, width, d, sa, sb):
            """Scattering a width-w sliced update into the shared supernet
            writes the kept prefix and NOTHING else: pruned coordinates
            keep the host tree's values bit-exact (the gradient
            scatter-back contract for mask-aware aggregation)."""
            host = SN.split_params(CFG, _params(0), d)[0]
            ra, rb = np.random.default_rng(sa), np.random.default_rng(sb)
            host = jax.tree.map(
                lambda x: jnp.asarray(ra.normal(size=x.shape), x.dtype),
                host)
            update_full = jax.tree.map(
                lambda x: jnp.asarray(rb.normal(size=x.shape), x.dtype),
                host)
            update = SN.slice_width(CFG, update_full, width)
            out = SN.scatter_width(CFG, host, update, width)
            got = jax.tree_util.tree_flatten_with_path(out)[0]
            want_new = jax.tree_util.tree_flatten_with_path(update_full)[0]
            for (g, w_, (path, h, kept)) in zip(
                    got, want_new, _plan_masks(CFG, host, width)):
                g, w_ = np.asarray(g[1]), np.asarray(w_[1])
                h = np.asarray(h)
                if kept is None:    # fully-held leaf: replaced whole
                    np.testing.assert_array_equal(g, w_)
                    continue
                axis, mask = kept
                keep_idx = tuple(
                    mask if i == axis else slice(None)
                    for i in range(g.ndim))
                drop_idx = tuple(
                    ~mask if i == axis else slice(None)
                    for i in range(g.ndim))
                np.testing.assert_array_equal(g[keep_idx], w_[keep_idx])
                np.testing.assert_array_equal(g[drop_idx], h[drop_idx])
else:   # pragma: no cover - hypothesis in [dev] extras, absent on tier-1
    class TestSliceParityProperties:
        def test_slice_parity_properties(self):
            pytest.skip("hypothesis not installed")


# ------------------------------------------------- width=1.0 is identity

class TestFullWidthIdentity:
    def test_width_cfg_identity(self):
        assert SN.width_cfg(CFG, 1.0) is CFG

    def test_views_identity(self):
        client = SN.split_params(CFG, _params(0), 2)[0]
        assert SN.slice_width(CFG, client, 1.0) is client
        assert SN.mask_width(CFG, client, 1.0) is client
        assert SN.widen_width(CFG, client, 1.0) is client
        assert SN.scatter_width(CFG, client, client, 1.0) is client

    def test_split_params_default_matches_full_width(self):
        params = _params(0)
        for a, b in zip(
                jax.tree.leaves(SN.split_params(CFG, params, 2)),
                jax.tree.leaves(SN.split_params(CFG, params, 2, 1.0))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gqa_groups_stay_whole(self):
        """Kept query heads must never read a pruned KV head: n_heads
        slices by whole GQA groups at every tier."""
        for w in (0.2, 0.25, 0.4, 0.5, 0.6, 0.75, 0.9):
            wcfg = SN.width_cfg(CFG, w)
            group = CFG.n_heads // CFG.n_kv_heads
            assert wcfg.n_heads == group * wcfg.n_kv_heads
            assert wcfg.head_dim == CFG.resolved_head_dim
            assert 1 <= wcfg.n_kv_heads <= CFG.n_kv_heads
            assert 1 <= wcfg.d_ff <= CFG.d_ff

    def test_client_param_bytes_monotone_in_width(self):
        params = _params(0)
        sizes = [SN.client_param_bytes(CFG, params, 2, w)
                 for w in (0.25, 0.5, 0.75, 1.0)]
        assert sizes == sorted(sizes)
        assert sizes[0] < sizes[-1]


# ------------------------------------------- engine-level width behavior

class TestWidthEngine:
    def test_full_width_ladder_is_bit_exact_noop(self):
        """width_tiers=(1.0,) routes through the width-grouping machinery
        but must land bit-identical to the legacy no-ladder engine."""
        a = _engine("ssfl")
        b = _engine("ssfl", width_tiers=(1.0,))
        for _ in range(2):
            a.run_round()
            b.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    @pytest.mark.parametrize("method", ["ssfl", "sfl", "dfl"])
    def test_heterogeneous_width_round_runs(self, method):
        eng = _engine(method, width_tiers=(0.5, 1.0))
        widths = eng.state.fleet.widths
        assert set(np.unique(widths)) <= {0.5, 1.0}
        assert (widths < 1.0).any(), "ladder produced no narrow client"
        rec = eng.run_round()
        assert np.isfinite(rec["loss"])

    def test_hasfl_co_tunes_widths(self):
        from repro.federated.strategies.hasfl import HASFL
        eng = _engine(HASFL(width_tiers=(0.5, 1.0)))
        eng.run_round()
        widths = eng.state.fleet.widths
        assert set(np.unique(widths)) <= {0.5, 1.0}
        assert np.isfinite(eng.run_round()["loss"])

    def test_width_resume_bit_identical(self):
        """2 uninterrupted heterogeneous-width rounds == 1 round + save +
        fresh engine + restore + 1 round, bit for bit — and the width
        tiers themselves survive the checkpoint (they ride the engine
        stream metadata; fleet profiles are reconstructed from the seed,
        the widths must NOT be)."""
        mk = lambda: _engine("ssfl", optimizer="adamw", lr=0.01,
                             availability=0.7, sample_frac=0.8,
                             width_tiers=(0.5, 1.0))
        a = mk()
        assert (a.state.fleet.widths < 1.0).any()
        a.run_round()
        a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            b.save(path)
            c = mk()
            # sabotage the reconstructed widths: restore must overwrite
            c.state.fleet.widths = np.ones_like(c.state.fleet.widths)
            c.restore(path)
            np.testing.assert_array_equal(c.state.fleet.widths,
                                          b.state.fleet.widths)
            assert c.state.round_idx == 1
            c.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a.state.local_heads),
                        jax.tree.leaves(c.state.local_heads)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

"""Runtime-depth parity and the PR's behavioral bugfix regressions.

Depth became a RUNTIME kernel quantity (masked scan over the full layer
stack, ``model.run_stack``): these tests pin the contract that the masked
path is BIT-EXACT against the trace-time static-slice path, that inactive
stack rows receive exactly-zero gradients, and regression-test the three
behavioral fixes that rode along — ``fused_loss`` honoring the TPGF
fusion-rule variant, hasfl's smashed-activation pricing deriving bytes
from ``cfg.dtype``, and ``make_dummy_batch`` drawing labels from their own
RNG stream in the enc-dec/vlm branches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.configs.base import InputShape
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.federated import Engine
from repro.models import model as M


def _cfg(**kw):
    d = dict(n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
             d_ff=96, image_size=16, n_classes=6)
    d.update(kw)
    return base.get_reduced("vit16_cifar").replace(**d)


def _setup(seed=0, **kw):
    cfg = _cfg(**kw)
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, rng)
    batch = M.make_dummy_batch(cfg, InputShape("t", 16, 4, "train"), rng)
    return cfg, params, batch


def _assert_bitexact(a, b, what):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


class TestRuntimeDepthParity:
    """static int d (slice) vs jax scalar d (masked scan): bit-exact."""

    @pytest.mark.parametrize("d", [1, 2, 3, 4])
    def test_prefix_apply(self, d):
        cfg, params, batch = _setup()
        zs, _ = M.prefix_apply(cfg, params, batch, d)
        zr, _ = M.prefix_apply(cfg, params, batch, jnp.int32(d))
        _assert_bitexact(zs, zr, f"prefix d={d}")

    @pytest.mark.parametrize("d", [1, 2, 3, 4])
    def test_suffix_apply(self, d):
        cfg, params, batch = _setup()
        z, _ = M.prefix_apply(cfg, params, batch, d)
        ls, _ = M.suffix_apply(cfg, params, z, batch, d)
        lr, _ = M.suffix_apply(cfg, params, z, batch, jnp.int32(d))
        _assert_bitexact(ls, lr, f"suffix d={d}")

    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_tpgf_grads(self, d):
        cfg, params, batch = _setup()
        s = T.tpgf_grads(cfg, params, batch, d)
        r = T.tpgf_grads(cfg, params, batch, jnp.int32(d))
        _assert_bitexact(s.grads, r.grads, f"tpgf grads d={d}")
        for name in ("loss_client", "loss_server", "w_client"):
            np.testing.assert_array_equal(np.asarray(getattr(s, name)),
                                          np.asarray(getattr(r, name)),
                                          err_msg=f"{name} d={d}")

    @pytest.mark.parametrize("d", [1, 3])
    def test_tpgf_grads_degraded(self, d):
        """Fault-tolerant degrade path: parity must also hold when the
        server is unreachable (w collapses to 1, server grads zero)."""
        cfg, params, batch = _setup()
        av = jnp.asarray(False)
        s = T.tpgf_grads(cfg, params, batch, d, server_available=av)
        r = T.tpgf_grads(cfg, params, batch, jnp.int32(d),
                         server_available=av)
        _assert_bitexact(s.grads, r.grads, f"degraded grads d={d}")
        np.testing.assert_array_equal(np.asarray(s.w_client),
                                      np.asarray(r.w_client))

    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_inactive_rows_zero_gradient(self, d):
        """The masked scan's ``where`` guarantees exactly-zero cotangents
        for stack rows outside the active window — the invariant the
        kernels' in-kernel row freeze and the aggregation zero-pad rely
        on."""
        cfg, params, batch = _setup()
        client_p, server_p, local_p = SN.split_params(cfg, params, None)

        def client_loss(cp):
            z, _ = M.client_apply(cfg, cp, batch, length=jnp.int32(d))
            return jnp.sum(z * z)

        g = jax.grad(client_loss)(client_p)
        sname = SN.split_stack_name(cfg)
        for leaf in jax.tree.leaves(g[sname]):
            rows = np.asarray(leaf)
            assert (rows[d:] == 0).all(), "suffix rows leaked into prefix"
            assert np.abs(rows[:d]).sum() > 0, "prefix rows got no signal"

        def server_loss(sp):
            z, _ = M.client_apply(cfg, client_p, batch,
                                  length=jnp.int32(d))
            return M.server_split_loss(cfg, sp, z, batch,
                                       length=jnp.int32(d))

        gs = jax.grad(server_loss)(server_p)
        for leaf in jax.tree.leaves(gs[sname]):
            rows = np.asarray(leaf)
            assert (rows[:d] == 0).all(), "prefix rows leaked into suffix"
            assert np.abs(rows[d:]).sum() > 0, "suffix rows got no signal"


class TestFusedLossVariant:
    """Regression: ``fused_loss`` hardcoded the "full" rule, so Fig. 6
    ablation runs recorded Eq. 6 weights that disagreed with the update
    actually applied. It must honor ``variant`` exactly like
    ``tpgf_weight``."""

    L_C, L_S, D_I, D_S = 2.0, 0.5, 1, 3

    def _hand(self, w):
        return w * self.L_C + (1.0 - w) * self.L_S

    def test_variants_match_hand_computed_weights(self):
        eps = 1e-8
        ic, is_ = 1.0 / (self.L_C + eps), 1.0 / (self.L_S + eps)
        depth, loss_term = self.D_I / (self.D_I + self.D_S), ic / (ic + is_)
        expect = {"full": depth * loss_term, "no_loss": depth,
                  "no_depth": loss_term, "equal": 0.5}
        for variant, w in expect.items():
            got = float(T.fused_loss(self.L_C, self.L_S, self.D_I, self.D_S,
                                     eps, variant))
            np.testing.assert_allclose(got, self._hand(w), rtol=1e-6,
                                       err_msg=variant)

    def test_variants_actually_differ(self):
        vals = {v: float(T.fused_loss(self.L_C, self.L_S, self.D_I,
                                      self.D_S, 1e-8, v))
                for v in ("full", "no_loss", "no_depth", "equal")}
        assert len(set(vals.values())) == 4, vals

    def test_matches_tpgf_weight(self):
        for variant in ("full", "no_loss", "no_depth", "equal"):
            w = T.tpgf_weight(self.L_C, self.L_S, self.D_I, self.D_S,
                              1e-8, variant)
            np.testing.assert_allclose(
                float(T.fused_loss(self.L_C, self.L_S, self.D_I, self.D_S,
                                   1e-8, variant)),
                self._hand(float(w)), rtol=1e-6)


class TestHASFLCommPricing:
    """Regression: hasfl's ``comm_cost`` priced smashed activations at a
    hardcoded 4 bytes/element; it must derive itemsize from ``cfg.dtype``
    (bf16 smashed traffic is 2 bytes/element, half of f32's)."""

    def _engine(self, dtype):
        cfg = _cfg().replace(dtype=dtype)
        return Engine(cfg, 4, "hasfl", seed=0, lr=0.1, local_steps=2,
                      batch_size=4)

    def test_bf16_priced_by_hand(self):
        eng = self._engine("bfloat16")
        d = 2
        cost, msgs = eng.strategy.comm_cost(eng, d, True)
        pbytes = SN.client_param_bytes(eng.cfg, eng.state.params, d)
        # 2 bytes/element for bf16 — the hand-computed pricing
        per_tok = eng.tokens_per_sample() * eng.cfg.d_model * 2
        per_step = 2 * int(float(eng.batch_size) * per_tok)
        assert cost == 2 * pbytes + eng.local_steps * per_step
        assert msgs == 2 + 2 * eng.local_steps

    def test_bf16_smashed_half_of_f32(self):
        d = 2
        costs = {}
        for dtype in ("float32", "bfloat16"):
            eng = self._engine(dtype)
            cost, _ = eng.strategy.comm_cost(eng, d, True)
            zero, _ = eng.strategy.comm_cost(eng, d, False)
            costs[dtype] = cost - zero   # isolate the smashed-traffic term
        assert costs["float32"] == 2 * costs["bfloat16"] > 0


class TestDummyBatchKeys:
    """Regression: the enc-dec/vlm ``make_dummy_batch`` branches drew
    tokens and labels from the SAME key (identical arrays for enc-dec, a
    correlated shared stream for vlm); labels must come from their own
    fold. The dense/vit branches must stay byte-identical to the original
    two-way split draws."""

    def test_encdec_labels_independent(self):
        cfg = base.get_reduced("whisper_small")
        assert cfg.is_encdec
        b = M.make_dummy_batch(cfg, InputShape("t", 16, 2, "train"),
                               jax.random.PRNGKey(0))
        assert not np.array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b["labels"]))
        _, k2 = jax.random.split(jax.random.PRNGKey(0))
        want = jax.random.randint(jax.random.fold_in(k2, 1),
                                  b["labels"].shape, 0, cfg.vocab)
        np.testing.assert_array_equal(np.asarray(b["labels"]),
                                      np.asarray(want))

    def test_vlm_labels_independent(self):
        cfg = base.get_reduced("internvl2_2b")
        assert cfg.family == "vlm"
        sh = InputShape("t", 16 + cfg.n_patches, 2, "train")
        b = M.make_dummy_batch(cfg, sh, jax.random.PRNGKey(3))
        assert not np.array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b["labels"]))
        _, k2 = jax.random.split(jax.random.PRNGKey(3))
        want = jax.random.randint(jax.random.fold_in(k2, 1),
                                  b["labels"].shape, 0, cfg.vocab)
        np.testing.assert_array_equal(np.asarray(b["labels"]),
                                      np.asarray(want))

    def test_dense_and_vit_byte_identical(self):
        """The fix must not move dense/vit draws (seed goldens depend on
        them): reproduce the original two-way split by hand."""
        vit = _cfg()
        rng = jax.random.PRNGKey(0)
        b = M.make_dummy_batch(vit, InputShape("t", 16, 4, "train"), rng)
        k1, k2 = jax.random.split(rng)
        np.testing.assert_array_equal(
            np.asarray(b["images"]),
            np.asarray(jax.random.normal(
                k1, (4, vit.image_size, vit.image_size, 3),
                jnp.dtype(vit.dtype))))
        np.testing.assert_array_equal(
            np.asarray(b["label"]),
            np.asarray(jax.random.randint(k2, (4,), 0, vit.n_classes)))

        dense = base.get_reduced("llama3_2_3b")
        rng = jax.random.PRNGKey(1)
        b = M.make_dummy_batch(dense, InputShape("t", 16, 2, "train"), rng)
        k1, k2 = jax.random.split(rng)
        np.testing.assert_array_equal(
            np.asarray(b["tokens"]),
            np.asarray(jax.random.randint(k1, (2, 16), 0, dense.vocab)))
        np.testing.assert_array_equal(
            np.asarray(b["labels"]),
            np.asarray(jax.random.randint(k2, (2, 16), 0, dense.vocab)))

"""Sharding-rule validation with an abstract 16x16 / 2x16x16 mesh:
every PartitionSpec axis must divide its dimension for EVERY assigned
architecture (this is what makes the dry-run lower)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_abstract_mesh

MESH_1POD = make_abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(shapes_tree, specs_tree, mesh, where):
    flat_s, _ = jax.tree_util.tree_flatten_with_path(shapes_tree)
    flat_p = jax.tree_util.tree_leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (
                f"{where}: {jax.tree_util.keystr(path)} dim{dim}="
                f"{leaf.shape[dim]} not divisible by {axes}={size}")


@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_param_specs_divisible(arch, mesh):
    cfg = base.get_config(arch)
    shapes = ST.params_specs(cfg)
    specs = SH.param_pspecs(cfg, shapes, mesh)
    _check_divisible(shapes, specs, mesh, arch)


@pytest.mark.parametrize("arch", ["gemma_2b", "hymba_1_5b", "whisper_small",
                                  "mamba2_2_7b", "grok_1_314b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    if base.skip_reason(arch, shape_name):
        pytest.skip("by design")
    cfg = base.get_config(arch)
    shape = base.INPUT_SHAPES[shape_name]
    cshapes = ST.cache_specs(cfg, shape)
    specs = SH.cache_pspecs(cfg, cshapes, MESH_1POD)
    _check_divisible(cshapes, specs, MESH_1POD, f"{arch}/{shape_name}")


def test_tricky_head_fallbacks():
    """whisper 12H & hymba 25H don't divide 16, but the flattened H*hd
    projections do — heads must never produce an invalid spec."""
    for arch in ("whisper_small", "hymba_1_5b", "gemma_2b"):
        cfg = base.get_config(arch)
        shapes = ST.params_specs(cfg)
        specs = SH.param_pspecs(cfg, shapes, MESH_1POD)
        _check_divisible(shapes, specs, MESH_1POD, arch)


def test_seq_cache_variant():
    cfg = base.get_config("internlm2_1_8b").replace(decode_cache_shard="seq")
    shape = base.INPUT_SHAPES["decode_32k"]
    specs = SH.cache_pspecs(cfg, ST.cache_specs(cfg, shape), MESH_1POD)
    assert specs["k"][2] == "model"          # W sharded over tensor axis
    assert specs["k"][3] is None and specs["k"][4] is None


def test_vocab_padding_sharding():
    for arch in base.ARCH_IDS:
        cfg = base.get_config(arch)
        assert cfg.padded_vocab % 16 == 0


class TestFleetAxis:
    """Client-axis sharding for the federated engine's stacked structures."""

    def test_fleet_pspecs_shard_when_divisible(self):
        tree = {"local_head": jax.ShapeDtypeStruct((32, 48, 6), np.float32),
                "local_head_bias": jax.ShapeDtypeStruct((32, 6), np.float32)}
        specs = SH.fleet_pspecs(tree, MESH_1POD)
        assert specs["local_head"] == P(("data",), None, None)
        assert specs["local_head_bias"] == P(("data",), None)

    def test_fleet_pspecs_replicate_small_fleets(self):
        tree = {"local_head": jax.ShapeDtypeStruct((6, 48, 6), np.float32)}
        specs = SH.fleet_pspecs(tree, MESH_1POD)   # 6 % 16 != 0
        assert specs["local_head"] == P(None, None, None)

    def test_fleet_pspecs_scalar_leaves_replicate_rank0(self):
        """0-d leaves must get the rank-0 spec P() — a P(None) would be
        longer than the leaf's rank and NamedSharding rejects it."""
        tree = {"counter": jax.ShapeDtypeStruct((), np.int32),
                "stacked": jax.ShapeDtypeStruct((32, 3), np.float32)}
        specs = SH.fleet_pspecs(tree, MESH_1POD)
        assert specs["counter"] == P()
        assert specs["stacked"] == P(("data",), None)

    def test_engine_accepts_mesh(self):
        """End-to-end on a 1-device fleet mesh: heads are placed with the
        client-axis sharding and a round still runs."""
        from jax.sharding import Mesh
        from repro.configs import base as B
        from repro.federated import Engine
        cfg = B.get_reduced("vit16_cifar").replace(
            n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
            d_ff=96, image_size=16, n_classes=6)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
        eng = Engine(cfg, 4, "ssfl", seed=0, lr=0.3, local_steps=1,
                     batch_size=4, mesh=mesh)
        head = jax.tree.leaves(eng.state.local_heads)[0]
        assert head.sharding.spec[0] == ("data",)
        assert np.isfinite(eng.run_round()["loss"])


# ------------------------------------------------------------- properties
#
# Hypothesis guard scoped to the class (tests/test_core.py's importorskip
# pattern would skip this whole module, which must keep running without
# hypothesis).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    class TestFleetPspecsProperty:
        """For random leaf shapes and mesh sizes, every spec
        ``fleet_pspecs`` returns must be divisibility-valid, never longer
        than the leaf's rank, and scalar/0-d leaves must replicate."""

        @settings(max_examples=50, deadline=None)
        @given(shapes=st.lists(st.lists(st.integers(1, 24), min_size=0,
                                        max_size=3),
                               min_size=1, max_size=6),
               data=st.sampled_from([1, 2, 3, 4, 8, 16]),
               pod=st.sampled_from([None, 2]))
        def test_specs_valid(self, shapes, data, pod):
            from repro.launch.mesh import make_abstract_mesh
            if pod is None:
                mesh = make_abstract_mesh((data, 2), ("data", "model"))
                extent = data
            else:
                mesh = make_abstract_mesh((pod, data, 2),
                                          ("pod", "data", "model"))
                extent = pod * data
            tree = {f"leaf{i}": jax.ShapeDtypeStruct(tuple(s), np.float32)
                    for i, s in enumerate(shapes)}
            specs = SH.fleet_pspecs(tree, mesh)
            for i, shape in enumerate(shapes):
                spec = specs[f"leaf{i}"]
                assert len(spec) <= len(shape), (shape, spec)
                if not shape:
                    assert spec == P()
                    continue
                if shape[0] % extent == 0:
                    assert spec[0] == SH.fleet_axes(mesh)
                else:
                    assert spec[0] is None
                assert all(ax is None for ax in tuple(spec)[1:])
else:   # pragma: no cover - hypothesis in [dev] extras, absent on tier-1
    class TestFleetPspecsProperty:
        def test_specs_valid(self):
            pytest.skip("hypothesis not installed")

"""Accounting + kernel-path integration tests."""
import jax
import numpy as np
import pytest

from repro.configs import base
from repro.core import aggregation as AGG
from repro.core import supernet as SN
from repro.federated import metrics as MET
from repro.models import model as M


class TestAccounting:
    def test_round_stats_sync_barrier(self):
        a = MET.RoundStats(comm_bytes=10, round_time_s=2.0)
        b = MET.RoundStats(comm_bytes=5, round_time_s=7.0)
        a.add(b)
        assert a.comm_bytes == 15
        assert a.round_time_s == 7.0  # max, not sum (sync barrier)

    def test_accountant_energy_power(self):
        acc = MET.Accountant()
        acc.log_round(MET.RoundStats(round_time_s=10.0, energy_j=500.0))
        acc.log_round(MET.RoundStats(round_time_s=10.0, energy_j=300.0))
        assert acc.total_time_s == 20.0
        assert acc.avg_power_w == pytest.approx(40.0)
        assert acc.co2_g() == pytest.approx(800 / 3.6e6 * 0.4 * 1000)

    def test_comm_time_includes_latency(self):
        dm = MET.DeviceModel(bandwidth_mb_s=1.0)
        t = dm.comm_time_s(MET.MB, lat_ms=100.0, n_messages=2)
        assert t == pytest.approx(1.0 + 0.2)

    def test_flops_rule(self):
        assert MET.dense_train_flops(1000, 10) == 60000


class TestAggregationKernelPath:
    def test_pallas_path_matches_jnp_path(self):
        cfg = base.get_reduced("internlm2_1_8b")
        g = M.init_params(cfg, jax.random.PRNGKey(0))
        depths = [2, 1, 2]
        trees = [SN.split_params(
            cfg, M.init_params(cfg, jax.random.PRNGKey(i + 1)), d)[0]
            for i, d in enumerate(depths)]
        stacked = AGG.stack_client_trees(cfg, trees, depths)
        losses = [0.8, 1.3, 0.6]
        ref, _ = AGG.aggregate(cfg, g, stacked, depths, losses,
                               use_pallas=False)
        ker, _ = AGG.aggregate(cfg, g, stacked, depths, losses,
                               use_pallas=True)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-4, atol=1e-5),
            ref, ker)

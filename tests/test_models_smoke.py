"""Per-assigned-architecture smoke tests (reduced family variants).

For each arch: instantiate the REDUCED config, run one forward pass and one
TPGF train step on CPU, assert output shapes + no NaNs — the contract from
the architecture assignment block.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.configs.base import InputShape
from repro.core import tpgf as T
from repro.models import decode as D
from repro.models import model as M

ALL_ARCHS = base.ARCH_IDS + base.EXTRA_ARCH_IDS


def _shape_for(cfg):
    seq = 32 + (cfg.n_patches if cfg.family == "vlm" else 0)
    return InputShape("smoke", seq, 2, "train")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = base.get_reduced(arch)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    batch = M.make_dummy_batch(cfg, _shape_for(cfg), rng)
    d = cfg.resolved_split_depth

    z, aux = M.prefix_apply(cfg, params, batch, d)
    assert z.ndim == 3 and z.shape[-1] == cfg.d_model
    assert not np.isnan(np.asarray(z, np.float32)).any()

    out = T.tpgf_grads(cfg, params, batch, d)
    for name, val in (("loss_client", out.loss_client),
                      ("loss_server", out.loss_server)):
        v = float(val)
        assert np.isfinite(v) and v > 0, (arch, name, v)
    assert 0.0 <= float(out.w_client) <= 1.0

    # grads aligned with params, finite, and an SGD step reduces server loss
    jax.tree.map(lambda p, g: None if p.shape == g.shape else
                 pytest.fail(f"{arch}: grad shape mismatch"),
                 params, out.grads)
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                      params, out.grads)
    out2 = T.tpgf_grads(cfg, p2, batch, d)
    assert float(out2.loss_server) < float(out.loss_server), arch


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = base.get_reduced(arch)
    rng = jax.random.PRNGKey(1)
    params = M.init_params(cfg, rng)
    sh = InputShape("s", 16 + (cfg.n_patches if cfg.family == "vlm" else 0),
                    2, "prefill")
    batch = M.make_dummy_batch(cfg, sh, rng)
    logits, cache = D.prefill(cfg, params, batch, decode_budget=4)
    assert logits.shape[-1] == cfg.padded_vocab
    tok = jnp.zeros((2, 1), jnp.int32)
    l2, cache2 = D.decode_step(cfg, params, cache, tok)
    assert l2.shape == (2, 1, cfg.padded_vocab)
    assert int(cache2["idx"]) == int(cache["idx"]) + 1
    assert not np.isnan(np.asarray(l2, np.float32)).any()


def test_vit_has_no_decode():
    cfg = base.get_reduced("vit16_cifar")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        D.decode_step(cfg, params, {}, jnp.zeros((1, 1), jnp.int32))

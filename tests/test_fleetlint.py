"""fleetlint self-tests: every rule fires on its corpus bad-example and
stays silent on its good-example, src/repro lints clean, and every
suppression in src/repro carries a justification.

The corpus under ``tests/_fleetlint_corpus/`` is parsed by the linter,
never imported — the files reference ``register_kernel`` /
``register_strategy`` as bare names on purpose, matching how the linter
recognizes them (by name, not by import resolution).
"""
from __future__ import annotations

import re
from collections import Counter
from pathlib import Path

import pytest

from repro.analysis.fleetlint import (RULES, Finding, lint_paths,
                                      lint_source, main)

ROOT = Path(__file__).resolve().parent.parent
CORPUS = ROOT / "tests" / "_fleetlint_corpus"
SRC = ROOT / "src" / "repro"


def codes_for(name: str) -> Counter:
    return Counter(f.code for f in lint_paths([CORPUS / name]))


# ------------------------------------------------------------- corpus: bad

@pytest.mark.parametrize("name,code,count", [
    ("fl001_bad.py", "FL001", 6),   # 5 in-kernel syncs + 1 in a scan body
    ("fl002_bad.py", "FL002", 4),   # sum/mean axis=0, any, all axis=0
    ("fl002_width_bad.py", "FL002", 3),   # widened-stack sum/mean/any
    ("fl002_crosstier_bad.py", "FL002", 3),   # tier-axis sum/mean/any
    ("fl003_bad.py", "FL003", 7),   # literal psum, 2x arity x2, specless,
                                    # missing axis_name
    ("fl003_width_bad.py", "FL003", 3),   # out-arity, literal pmean,
                                          # specless (d,width)-keyed kernel
    ("fl003_crosstier_bad.py", "FL003", 3),   # in-arity, literal psum,
                                              # specless fusion kernel
    ("fl004_bad.py", "FL004", 5),   # time, global np, 2x unseeded, stdlib
    ("fl005_bad.py", "FL005", 5),   # 3 drifted hooks + 2 in the subclass
])
def test_bad_corpus_fires(name, code, count):
    got = codes_for(name)
    assert got[code] == count, f"{name}: {got}"
    assert set(got) == {code}, f"{name} leaked other rules: {got}"


def test_fl005_catches_subclass_drift():
    # DriftingChild has no decorator — it is reached transitively through
    # its registered parent, which is the whole point of the class graph.
    findings = lint_paths([CORPUS / "fl005_bad.py"])
    assert any("DriftingChild.fold_server" in f.message for f in findings)
    assert any("DriftingChild.aggregate" in f.message for f in findings)


def test_comm_cost_probe_message():
    findings = lint_paths([CORPUS / "fl005_bad.py"], select=["FL005"])
    probe = [f for f in findings if "comm_cost" in f.message]
    assert probe and "ids= probe" in probe[0].message


# ------------------------------------------------------------ corpus: good

@pytest.mark.parametrize("name", [
    "fl001_good.py", "fl002_good.py", "fl002_width_good.py",
    "fl002_crosstier_good.py", "fl003_good.py", "fl003_width_good.py",
    "fl003_crosstier_good.py", "fl004_good.py", "fl005_good.py",
])
def test_good_corpus_is_clean(name):
    assert lint_paths([CORPUS / name]) == []


def test_whole_corpus_totals():
    got = Counter(f.code for f in lint_paths([CORPUS]))
    assert got == {"FL001": 6, "FL002": 10, "FL003": 13,
                   "FL004": 5, "FL005": 5}


# ------------------------------------------------------- rule machinery

def test_suppression_and_select():
    src = ("# fleetlint: scope=fleet\n"
           "import jax.numpy as jnp\n"
           "import time\n"
           "def f(x):\n"
           "    t = time.time()\n"
           "    return jnp.sum(x, axis=0), t\n")
    codes = {f.code for f in lint_source(src, "case.py")}
    assert codes == {"FL002", "FL004"}
    only = lint_source(src, "case.py", select=["FL004"])
    assert {f.code for f in only} == {"FL004"}
    hushed = src.replace(
        "jnp.sum(x, axis=0), t",
        "jnp.sum(x, axis=0), t  # fleetlint: disable=FL002 — test")
    assert {f.code for f in lint_source(hushed, "case.py")} == {"FL004"}


def test_scope_pragma_gates_fleet_rules():
    src = "import time\ndef f():\n    return time.time()\n"
    assert lint_source(src, "tools_helper.py") == []          # out of scope
    assert lint_source("# fleetlint: scope=fleet\n" + src,
                       "tools_helper.py") != []               # pragma opts in
    assert lint_source(src, "federated/helper.py") != []      # path opts in


def test_finding_format_has_fixit():
    f = Finding("FL002", "a.py", 3, 1, "msg", "do this instead")
    out = f.format()
    assert "a.py:3:1: FL002" in out and "fix: do this instead" in out


# ------------------------------------------------------------ src/repro

def test_src_repro_is_clean():
    assert lint_paths([SRC]) == []


def test_every_suppression_is_justified():
    pat = re.compile(r"#\s*fleetlint:\s*disable=(?:FL\d{3}(?:\s*,\s*)?)+")
    for py in sorted(SRC.rglob("*.py")):
        for n, line in enumerate(py.read_text().splitlines(), 1):
            m = pat.search(line)
            if m:
                tail = line[m.end():].strip(" -—\t")
                assert tail, f"{py.name}:{n}: suppression needs a reason"


# ----------------------------------------------------------------- CLI

def test_main_exit_codes(tmp_path, capsys):
    bad = tmp_path / "round.py"
    bad.write_text("# fleetlint: scope=fleet\nimport time\n"
                   "def f():\n    return time.time()\n")
    assert main([str(bad)]) == 1
    assert "FL004" in capsys.readouterr().out
    assert main([str(SRC)]) == 0
    assert "clean" in capsys.readouterr().out
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert all(code in out for code in RULES)

"""Child process for tests/test_multidevice.py (not collected by pytest).

The parent spawns this under ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` (the test_dryrun_small.py pattern, so the flag never leaks into
the tier-1 process). Commands:

  parity <mesh_n> <method> [...]  — 2-round sharded-vs-replicated parity
  widthparity                     — the same parity for one width-
                                    heterogeneous cohort (width_tiers
                                    ladder, 8-device mesh)
  invariants                      — frozen-server + bit-identical resume
                                    under the sharded path
  compiles                        — O(widths x buckets) compile count and
                                    warm-cache stability under churn
  sanitize                        — Engine(sanitize=True) smoke on the
                                    forced-8-device mesh: 2 healthy rounds
                                    match the replicated engine, and an
                                    injected NaN still raises with slot
                                    attribution
  crosstier                       — cross-tier FUSED mixed-width cohorts
                                    (the ``cross_tier="fused"`` default):
                                    sharded==replicated 2-round parity,
                                    plus frozen-server and adamw-resume
                                    bit-identical under fusion

Each command prints ``<COMMAND>_OK`` lines the parent asserts on.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _cfg():
    from repro.configs import base
    return base.get_reduced("vit16_cifar").replace(
        n_layers=3, d_model=24, n_heads=2, n_kv_heads=2, head_dim=12,
        d_ff=48, image_size=16, n_classes=6)


def _mesh(n):
    import jax
    from jax.sharding import Mesh
    assert len(jax.devices()) >= n, (len(jax.devices()), n)
    return Mesh(np.asarray(jax.devices()[:n]), ("data",))


def _engines(method, mesh, **kw):
    """(replicated, sharded) engine pair on identical seeds/knobs."""
    from repro.federated import Engine
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 2)
    kw.setdefault("batch_size", 4)
    n = kw.pop("n_clients", 13)
    return (Engine(_cfg(), n, method, **kw),
            Engine(_cfg(), n, method, mesh=mesh, **kw))


def parity(mesh_n, *methods):
    """Per-seed 2-round parity of the sharded engine against the
    replicated one: losses, cost accounting, final params and local heads
    (fp32 tolerance — the shard-mapped pooled means psum partial sums, so
    reduction order differs). 13 clients deliberately do NOT divide the
    mesh: buckets pad to whole slots per shard, head storage falls back to
    replication, and parity must still hold."""
    import jax
    mesh = _mesh(int(mesh_n))
    for method in methods:
        rep, shd = _engines(method, mesh, availability=0.7, sample_frac=0.8)
        assert shd.fleet_shards == int(mesh_n)
        for _ in range(2):
            a, b = rep.run_round(), shd.run_round()
            nan = np.isnan(a["loss"]) and np.isnan(b["loss"])
            assert nan or abs(a["loss"] - b["loss"]) < 1e-4, (method, a, b)
            assert a["comm_mb"] == b["comm_mb"], (method, a, b)
        for name, ta, tb in (("params", rep.state.params, shd.state.params),
                             ("heads", rep.state.local_heads,
                              shd.state.local_heads)):
            for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
                np.testing.assert_allclose(
                    np.asarray(x), np.asarray(y), atol=1e-5, rtol=1e-5,
                    err_msg=f"{method}/{name}")
        print("PARITY_OK", method)


def widthparity():
    """Sharded == replicated for a width-HETEROGENEOUS cohort: the ladder
    splits the fleet into (depth, width) sub-cohorts, each riding the
    shared kernel's shard_map variant; losses, accounting and final state
    must match the replicated engine at fp32 tolerance."""
    import jax
    mesh = _mesh(8)
    rep, shd = _engines("ssfl", mesh, availability=0.7, sample_frac=0.8,
                        width_tiers=(0.5, 1.0))
    widths = rep.state.fleet.widths
    assert (widths < 1.0).any() and (widths >= 1.0).any(), widths
    np.testing.assert_array_equal(widths, shd.state.fleet.widths)
    for _ in range(2):
        a, b = rep.run_round(), shd.run_round()
        assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)
        assert a["comm_mb"] == b["comm_mb"], (a, b)
    for name, ta, tb in (("params", rep.state.params, shd.state.params),
                         ("heads", rep.state.local_heads,
                          shd.state.local_heads)):
        for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-5, rtol=1e-5,
                err_msg=name)
    print("WIDTHPARITY_OK ssfl")


def invariants():
    """The SPMD-fragile invariants, bit-exact under the sharded path."""
    import jax
    from repro.core.fault import AvailabilityModel
    mesh = _mesh(8)

    # frozen server: an unreachable round must be a bit-exact server no-op
    # even with carried adamw moments psum'd across shards
    _, eng = _engines("ssfl", mesh, optimizer="adamw", lr=0.05,
                      n_clients=8)
    eng.run_round()   # builds nonzero server moments
    eng.avail_model = AvailabilityModel(0.0)
    head = np.asarray(eng.state.params["head"]).copy()
    t = int(np.asarray(eng.state.opt_state["server"]["t"]))
    opt_leaves = [np.asarray(x).copy()
                  for x in jax.tree.leaves(eng.state.opt_state)]
    eng.run_round()
    np.testing.assert_array_equal(head, np.asarray(eng.state.params["head"]))
    assert int(np.asarray(eng.state.opt_state["server"]["t"])) == t
    for a, b in zip(opt_leaves, jax.tree.leaves(eng.state.opt_state)):
        np.testing.assert_array_equal(a, np.asarray(b))
    print("INVARIANTS_OK frozen_server")

    # resume: 2 uninterrupted sharded rounds == 1 round + save + fresh
    # sharded engine + restore + 1 round, bit for bit
    import tempfile
    mk = lambda: _engines("ssfl", mesh, optimizer="adamw", lr=0.01,
                          availability=0.7, sample_frac=0.8, n_clients=8)[1]
    a = mk()
    a.run_round()
    a.run_round()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        b = mk()
        b.run_round()
        b.save(path)
        c = mk()
        c.restore(path)
        assert c.state.round_idx == 1
        # restore must re-apply the client-axis placement (fleet_pspecs)
        head = jax.tree.leaves(c.state.local_heads)[0]
        assert head.sharding.spec[0] == ("data",), head.sharding
        c.run_round()
    for x, y in zip(jax.tree.leaves((a.state.params, a.state.local_heads,
                                     a.state.opt_state)),
                    jax.tree.leaves((c.state.params, c.state.local_heads,
                                     c.state.opt_state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print("INVARIANTS_OK resume")


def compiles():
    """Bounded compile under the sharded path: the compile count of a
    churning run stays O(widths x buckets) (strictly fewer programs than
    distinct cohort shapes) and the warm cache absorbs rounds 4-6."""
    from repro.federated import Engine, bucketing as BK
    mesh = _mesh(8)
    eng = Engine(_cfg(), 16, "ssfl", seed=0, lr=0.3, local_steps=2,
                 batch_size=4, sample_frac=0.6, mesh=mesh)
    shapes = set()          # what an unbucketed path would specialize on
    keys = set()            # (depth, bucket) the sharded path compiles
    strat, orig = eng.strategy, type(eng.strategy).cohorts

    def spy(self, engine, ctx):
        out = orig(self, engine, ctx)
        for d, ids in out.items():
            shapes.add((d, len(ids)))
            keys.add((d, engine.bucket_for(len(ids))))
        return out

    strat.cohorts = spy.__get__(strat)
    before = BK.kernel_compiles()
    for _ in range(3):
        eng.run_round()
    fresh = BK.kernel_compiles() - before
    assert len(shapes) > len(keys), shapes
    assert fresh <= len(keys), (fresh, keys)
    warm = BK.kernel_compiles()
    for _ in range(3):
        eng.run_round()
    assert BK.kernel_compiles() == warm
    print("COMPILES_OK", fresh, len(shapes), len(keys))


def crosstier():
    """Cross-tier TPGF fusion under the sharded path. A mixed-width
    cohort runs every tier's kernel from the same server snapshot and
    ``tpgf.fuse_tiers`` folds them into ONE update; the per-tier masses
    are global (psum'd) sums, so the fused trees come out replicated and
    sharded == replicated must hold at fp32 tolerance — while the
    SPMD-fragile invariants (frozen server, resume) stay bit-exact."""
    import jax
    from repro.core.fault import AvailabilityModel
    mesh = _mesh(8)

    # 2-round parity for a mixed-width FUSED cohort (the engine default)
    rep, shd = _engines("ssfl", mesh, availability=0.7, sample_frac=0.8,
                        width_tiers=(0.5, 1.0))
    assert rep.cross_tier == "fused" and shd.cross_tier == "fused"
    widths = rep.state.fleet.widths
    assert (widths < 1.0).any() and (widths >= 1.0).any(), widths
    for _ in range(2):
        a, b = rep.run_round(), shd.run_round()
        assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)
        assert a["comm_mb"] == b["comm_mb"], (a, b)
    for name, ta, tb in (("params", rep.state.params, shd.state.params),
                         ("heads", rep.state.local_heads,
                          shd.state.local_heads)):
        for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-5, rtol=1e-5,
                err_msg=name)
    print("CROSSTIER_OK parity")

    # frozen server: an all-unreachable round must stay a bit-exact
    # server no-op under fusion — every tier's mass is exactly 0, the
    # delta-mode where-guard returns the base trees, and the bookkeeping
    # (adamw t) falls back to the carried value
    _, eng = _engines("ssfl", mesh, optimizer="adamw", lr=0.05,
                      n_clients=8, width_tiers=(0.5, 1.0))
    w8 = eng.state.fleet.widths
    assert (w8 < 1.0).any() and (w8 >= 1.0).any(), w8
    eng.run_round()   # builds nonzero server moments through the fuse
    eng.avail_model = AvailabilityModel(0.0)
    head = np.asarray(eng.state.params["head"]).copy()
    t = int(np.asarray(eng.state.opt_state["server"]["t"]))
    opt_leaves = [np.asarray(x).copy()
                  for x in jax.tree.leaves(eng.state.opt_state)]
    eng.run_round()
    np.testing.assert_array_equal(head, np.asarray(eng.state.params["head"]))
    assert int(np.asarray(eng.state.opt_state["server"]["t"])) == t
    for a, b in zip(opt_leaves, jax.tree.leaves(eng.state.opt_state)):
        np.testing.assert_array_equal(a, np.asarray(b))
    print("CROSSTIER_OK frozen_server")

    # resume: 2 uninterrupted fused rounds == 1 + save + restore + 1,
    # bit for bit (the fused update is deterministic given the streams)
    import tempfile
    mk = lambda: _engines("ssfl", mesh, optimizer="adamw", lr=0.01,
                          availability=0.7, sample_frac=0.8, n_clients=8,
                          width_tiers=(0.5, 1.0))[1]
    a = mk()
    a.run_round()
    a.run_round()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        b = mk()
        b.run_round()
        b.save(path)
        c = mk()
        c.restore(path)
        assert c.state.round_idx == 1
        c.run_round()
    for x, y in zip(jax.tree.leaves((a.state.params, a.state.local_heads,
                                     a.state.opt_state)),
                    jax.tree.leaves((c.state.params, c.state.local_heads,
                                     c.state.opt_state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print("CROSSTIER_OK resume")


def sanitize():
    """Sanitizer mode under a fleet mesh: the checkified variant always
    runs replicated (see ``FleetKernel.sanitized``), so a mesh engine with
    ``sanitize=True`` must still complete healthy rounds at replicated
    parity — and still trip on an injected NaN."""
    from repro.federated import Engine
    from repro.federated.bucketing import SlotSanitizerError
    mesh = _mesh(8)
    rep, shd = _engines("ssfl", mesh, availability=0.7, n_clients=8,
                        sanitize=True)
    rep.sanitize = False   # plain replicated reference, same seed/knobs
    for _ in range(2):
        a, b = rep.run_round(), shd.run_round()
        assert abs(a["loss"] - b["loss"]) < 1e-5, (a, b)
    print("SANITIZE_OK healthy_mesh_rounds")

    eng = Engine(_cfg(), 8, "ssfl", seed=0, lr=0.3, local_steps=1,
                 batch_size=4, mesh=mesh, sanitize=True)
    eng.data["clients"][3].images[:] = float("nan")
    try:
        eng.run_round()
        raise AssertionError("poisoned round did not raise")
    except SlotSanitizerError as e:
        assert e.slots, e
    print("SANITIZE_OK nan_caught_under_mesh")


if __name__ == "__main__":
    cmd, args = sys.argv[1], sys.argv[2:]
    {"parity": parity, "widthparity": widthparity,
     "invariants": invariants, "compiles": compiles,
     "sanitize": sanitize, "crosstier": crosstier}[cmd](*args)

"""Scenario-strategy tests: Markov arrival processes, staleness-weighted
aggregation, HASFL depth/batch co-tuning, and cross-round optimizer state
(including bit-identical checkpoint resume)."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import base
from repro.core import allocation as AL
from repro.core.fault import AvailabilityModel, MarkovArrivalProcess
from repro.federated import Engine, get_strategy
from repro.federated.strategies.unstable import staleness_weights


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


def _engine(method, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 1)
    kw.setdefault("batch_size", 8)
    return Engine(_cfg(), kw.pop("n_clients", 6), method, **kw)


class TestArrivalProcess:
    def test_markov_marginals_match_stationary_rate(self):
        """The chain starts stationary, so the on-fraction over many
        (client, round) draws must match p_up / (p_up + p_down)."""
        for p_up, p_down in ((0.4, 0.2), (0.1, 0.3), (0.9, 0.1)):
            proc = MarkovArrivalProcess(p_up, p_down, seed=0)
            draws = np.stack([proc.draw(64) for _ in range(400)])
            want = p_up / (p_up + p_down)
            assert draws.mean() == pytest.approx(want, abs=0.03), (p_up,
                                                                   p_down)

    def test_markov_outages_are_correlated(self):
        """A Gilbert chain with sticky states must show longer same-state
        runs than an i.i.d. Bernoulli at the same marginal."""
        proc = MarkovArrivalProcess(0.1, 0.05, seed=1)   # pi_on = 2/3
        draws = np.stack([proc.draw(32) for _ in range(300)])
        flips = (draws[1:] != draws[:-1]).mean()
        # i.i.d. at pi=2/3 flips with prob 2*pi*(1-pi) = 4/9 per round
        assert flips < 0.2

    def test_straggler_draw_thins_participation(self):
        proc = MarkovArrivalProcess(0.5, 0.0, straggle_p=0.5, seed=0)
        draws = np.stack([proc.draw(64) for _ in range(200)])
        # chain saturates on (p_down=0), so only stragglers drop out
        assert draws[50:].mean() == pytest.approx(0.5, abs=0.05)

    def test_state_round_trip(self):
        a = MarkovArrivalProcess(0.4, 0.2, straggle_p=0.1, seed=3)
        for _ in range(5):
            a.draw(16)
        b = MarkovArrivalProcess(0.4, 0.2, straggle_p=0.1, seed=99)
        b.set_state(a.get_state())
        for _ in range(5):
            np.testing.assert_array_equal(a.draw(16), b.draw(16))

    def test_bernoulli_is_special_case(self):
        assert AvailabilityModel(1.0).draw(8).all()
        assert not AvailabilityModel(0.0).draw(8).any()
        frac = np.stack([AvailabilityModel(0.3, seed=0).draw(1000)]).mean()
        assert frac == pytest.approx(0.3, abs=0.05)


class TestStalenessWeights:
    def test_sum_to_one(self):
        w = staleness_weights(np.array([0.2, 0.5, 0.1]),
                              np.array([0, 4, 1]), gamma=1.0)
        assert w.sum() == pytest.approx(1.0)
        assert (w >= 0).all()

    def test_stale_clients_discounted(self):
        w = staleness_weights(np.array([0.5, 0.5]), np.array([0, 3]),
                              gamma=1.0)
        assert w[0] == pytest.approx(4 * w[1])   # (1+3)^-1 discount

    def test_gamma_zero_recovers_plain_normalization(self):
        base_w = np.array([0.2, 0.6, 0.2])
        w = staleness_weights(base_w, np.array([0, 9, 2]), gamma=0.0)
        np.testing.assert_allclose(w, base_w / base_w.sum())


class TestUnstableStrategy:
    def test_runs_end_to_end(self):
        eng = _engine("unstable", n_clients=8, local_steps=2)
        assert eng.participation is not None
        losses = [eng.run_round()["loss"] for _ in range(4)]
        assert any(np.isfinite(l) for l in losses)

    def test_engine_tracks_staleness(self):
        eng = _engine("unstable", n_clients=8)
        for _ in range(5):
            eng.run_round()
        # Markov outages must have produced at least one absent client
        assert eng._staleness.max() >= 1

    def test_explicit_participation_process_wins(self):
        proc = MarkovArrivalProcess(0.9, 0.05, seed=5)
        eng = _engine("unstable", n_clients=4, participation=proc)
        assert eng.participation is proc


class TestHASFL:
    def test_runs_end_to_end(self):
        eng = _engine("hasfl", n_clients=8, local_steps=2)
        rec = eng.run_round()
        assert np.isfinite(rec["loss"])

    @pytest.mark.parametrize("seed", [0, 3, 11])
    def test_cotuning_never_infeasible(self, seed):
        eng = _engine("hasfl", n_clients=12, seed=seed)
        eng.run_round()   # init_round re-solves the fleet
        fleet, strat = eng.state.fleet, eng.strategy
        assert (fleet.depths >= 1).all()
        assert (fleet.depths <= fleet.capacity).all()
        assert fleet.feasible.all()
        assert set(strat._bs.tolist()) <= set(strat.batch_choices)

    def test_cotuner_shrinks_stragglers(self):
        """Direct solver check: a slow tiny-memory device must get a
        smaller (depth, batch) than a fast large-memory one."""
        counts = np.array([0, 100, 200, 300, 400])
        depths, batches = AL.co_tune(
            capacity=np.array([4, 4]), mem_gb=np.array([16.0, 0.25]),
            lat_ms=np.array([20.0, 20.0]), client_params_by_depth=counts,
            tokens_per_sample=64, bytes_per_sample=64 * 48 * 4,
            batch_choices=(4, 8, 16, 32), base_batch=16)
        assert depths[1] <= depths[0]
        assert batches[1] <= batches[0]
        assert depths.min() >= 1 and batches.min() >= 4


class TestCrossRoundOptState:
    @pytest.mark.parametrize("opt", ["sgd_momentum", "adamw"])
    def test_server_moments_persist_across_rounds(self, opt):
        eng = _engine("ssfl", n_clients=5, optimizer=opt, lr=0.05)
        eng.run_round()
        assert "server" in eng.state.opt_state
        leaves = jax.tree.leaves(eng.state.opt_state["server"])
        assert any(np.abs(np.asarray(x)).sum() > 0 for x in leaves)
        if opt == "adamw":
            t1 = int(np.asarray(eng.state.opt_state["server"]["t"]))
            eng.run_round()
            t2 = int(np.asarray(eng.state.opt_state["server"]["t"]))
            assert t2 > t1 > 0   # the step counter keeps counting

    def test_splitfed_server_moments_persist(self):
        eng = _engine("sfl", n_clients=5, optimizer="adamw", lr=0.01)
        eng.run_round()
        assert int(np.asarray(eng.state.opt_state["server"]["t"])) > 0

    def test_optimizer_switch_reinitializes(self):
        eng = _engine("ssfl", n_clients=4, optimizer="adamw", lr=0.01)
        eng.run_round()
        from repro.optim import get_optimizer
        eng.optimizer = get_optimizer("sgd_momentum", 0.05)
        rec = eng.run_round()   # stored adamw state must not be reused
        assert np.isfinite(rec["loss"])
        assert "mu" in eng.state.opt_state["server"]


class TestFrozenServerInvariant:
    """A cohort that never reaches the server must be a bit-exact server
    no-op even with carried momentum (tpgf's 'frozen server' fallback)."""

    @pytest.mark.parametrize("method", ["ssfl", "sfl"])
    def test_unreachable_round_freezes_server_branch(self, method):
        eng = _engine(method, n_clients=4, optimizer="adamw", lr=0.05,
                      local_steps=2)
        eng.run_round()   # builds nonzero server moments
        eng.avail_model = AvailabilityModel(0.0)
        head = np.asarray(eng.state.params["head"]).copy()
        t = int(np.asarray(eng.state.opt_state["server"]["t"]))
        opt_leaves = [np.asarray(x).copy()
                      for x in jax.tree.leaves(eng.state.opt_state)]
        eng.run_round()
        np.testing.assert_array_equal(head,
                                      np.asarray(eng.state.params["head"]))
        assert int(np.asarray(eng.state.opt_state["server"]["t"])) == t
        for a, b in zip(opt_leaves,
                        jax.tree.leaves(eng.state.opt_state)):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_stalled_clients_get_no_weight_decay(self):
        """SplitFed stalled clients must not drift: zeroed gradients must
        not become weight-decay steps on their client copies."""
        from repro.optim import adamw
        eng = _engine("sfl", n_clients=4, local_steps=2,
                      optimizer=adamw(0.05, weight_decay=0.1))
        eng.run_round()
        eng.avail_model = AvailabilityModel(0.0)
        before = [np.asarray(x).copy()
                  for x in jax.tree.leaves(eng.state.params)]
        eng.run_round()
        for a, b in zip(before, jax.tree.leaves(eng.state.params)):
            np.testing.assert_allclose(a, np.asarray(b), atol=1e-6)


class TestBitIdenticalResume:
    def _mk(self, **kw):
        return _engine("ssfl", n_clients=6, optimizer="adamw", lr=0.01,
                       local_steps=2, availability=0.7, sample_frac=0.8,
                       **kw)

    def test_adamw_resume_bit_identical(self):
        """2 uninterrupted rounds == 1 round + save + fresh engine +
        restore + 1 round, bit for bit (params, heads, opt state)."""
        a = self._mk()
        a.run_round()
        a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = self._mk()
            b.run_round()
            b.save(path)
            c = self._mk()
            c.restore(path)
            assert c.state.round_idx == 1
            c.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a.state.local_heads),
                        jax.tree.leaves(c.state.local_heads)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a.state.opt_state),
                        jax.tree.leaves(c.state.opt_state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_every_rng_stream_survives_resume(self):
        """The full RNG-stream audit, as one test: a setting that draws
        from EVERY round-path stream each round — batch (seed, in
        TrainState), availability Bernoulli (seed+7), cohort sampling
        (seed+13), Markov participation (seed+21) plus the staleness /
        server-update counters — must resume bit-identically. Any stream
        missing from Engine.save/restore desyncs some round after resume
        and shows up here as a loss/params mismatch."""
        mk = lambda: _engine("unstable", n_clients=6, availability=0.8,
                             sample_frac=0.5)
        a = mk()
        for _ in range(3):
            a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            b.save(path)
            c = mk()
            c.restore(path)
            # stream positions restore exactly, not just "close enough"
            assert c.state.rng.bit_generator.state == \
                b.state.rng.bit_generator.state
            assert c._sample_rng.bit_generator.state == \
                b._sample_rng.bit_generator.state
            assert c.avail_model.get_state() == b.avail_model.get_state()
            assert c.participation.get_state() == b.participation.get_state()
            np.testing.assert_array_equal(c._staleness, b._staleness)
            assert c._server_updates == b._server_updates
            c.run_round()
            c.run_round()
        assert [r["loss"] for r in a.history[1:]] == \
            [r["loss"] for r in c.history]
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_unstable_resume_replays_markov_state(self):
        mk = lambda: _engine("unstable", n_clients=6)
        a = mk()
        for _ in range(3):
            a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            b.save(path)
            c = mk()
            c.restore(path)
            np.testing.assert_array_equal(c._staleness, b._staleness)
            c.run_round()
            c.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFedAvgM:
    """Server momentum for fedavg (FedAvgM, Hsu et al.) on the shared
    ``opt_state["server"]`` slot — checkpoint-resumable."""

    def test_momentum_persists_in_server_slot(self):
        eng = _engine("fedavgm", n_clients=4, local_steps=2)
        eng.run_round()
        assert "mu" in eng.state.opt_state["server"]
        leaves = jax.tree.leaves(eng.state.opt_state["server"])
        assert any(np.abs(np.asarray(x)).sum() > 0 for x in leaves)

    def test_momentum_accelerates_vs_plain_fedavg(self):
        """With beta>0 the second round's params must differ from plain
        FedAvg's (same seed, same draws) — the momentum actually folds."""
        a = _engine("fedavg", n_clients=4)
        b = _engine("fedavgm", n_clients=4)
        for _ in range(2):
            a.run_round(), b.run_round()
        diffs = [float(np.abs(np.asarray(x) - np.asarray(y)).max())
                 for x, y in zip(jax.tree.leaves(a.state.params),
                                 jax.tree.leaves(b.state.params))]
        assert max(diffs) > 1e-6

    def test_zero_momentum_is_exact_fedavg(self):
        """beta=0 must take the no-momentum code path (float-identical to
        the plain average, and no server slot is ever created)."""
        from repro.federated.strategies.fedavg import FedAvg
        a = _engine("fedavg", n_clients=4)
        b = _engine(FedAvg(server_momentum=0.0), n_clients=4)
        a.run_round(), b.run_round()
        assert "server" not in b.state.opt_state
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_fedavgm_resume_bit_identical(self):
        """2 uninterrupted fedavgm rounds == 1 round + save + fresh engine
        + restore + 1 round, bit for bit (params AND momentum)."""
        mk = lambda: _engine("fedavgm", n_clients=4, local_steps=2,
                             sample_frac=0.8)
        a = mk()
        a.run_round()
        a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            b.save(path)
            c = mk()
            c.restore(path)
            c.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a.state.opt_state["server"]),
                        jax.tree.leaves(c.state.opt_state["server"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFedOptStrategies:
    """fedadam / fedyogi on the shared ``opt_state["server"]`` slot —
    exactly the fedavgm discipline, checkpoint-resumable bit for bit."""

    @pytest.mark.parametrize("name", ["fedadam", "fedyogi"])
    def test_moments_persist_in_server_slot(self, name):
        eng = _engine(name, n_clients=4, local_steps=2)
        eng.run_round()
        slot = eng.state.opt_state["server"]
        assert sorted(slot) == ["m", "v"]
        assert any(np.abs(np.asarray(x)).sum() > 0
                   for x in jax.tree.leaves(slot))

    @pytest.mark.parametrize("name", ["fedadam", "fedyogi"])
    def test_adaptive_fold_differs_from_plain_fedavg(self, name):
        a = _engine("fedavg", n_clients=4)
        b = _engine(name, n_clients=4)
        for _ in range(2):
            a.run_round(), b.run_round()
        diffs = [float(np.abs(np.asarray(x) - np.asarray(y)).max())
                 for x, y in zip(jax.tree.leaves(a.state.params),
                                 jax.tree.leaves(b.state.params))]
        assert max(diffs) > 1e-6

    @pytest.mark.parametrize("name", ["fedadam", "fedyogi"])
    def test_resume_bit_identical(self, name):
        """2 uninterrupted rounds == 1 round + save + fresh engine +
        restore + 1 round, bit for bit (params AND both moments) — the
        fedavgm resume test, under each adaptive member."""
        mk = lambda: _engine(name, n_clients=4, local_steps=2,
                             sample_frac=0.8)
        a = mk()
        a.run_round()
        a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            b.save(path)
            c = mk()
            c.restore(path)
            c.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(c.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a.state.opt_state["server"]),
                        jax.tree.leaves(c.state.opt_state["server"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestRegistryIntegration:
    @pytest.mark.parametrize("name", ["unstable", "hasfl"])
    def test_get_strategy_round_trip(self, name):
        strat = get_strategy(name)
        assert strat.name == name

    def test_legacy_prepare_fleet_signature_still_works(self):
        """Strategies written against the PR-1 two-argument hook must keep
        constructing (the engine only passes device_model when accepted)."""
        from repro.federated.strategies.ssfl import SuperSFL

        class Legacy(SuperSFL):
            def prepare_fleet(self, cfg, fleet):
                self.saw_fleet = fleet.n_clients

        eng = _engine(Legacy(), n_clients=4)
        assert eng.strategy.saw_fleet == 4
        assert np.isfinite(eng.run_round()["loss"])


class TestEvalModes:
    def test_fedavg_serverless_auto_eval_uses_global_head(self):
        """FedAvg trains the full model locally even at 0% availability,
        so auto eval must use the (trained) global head, not the untrained
        local phi ensemble."""
        eng = _engine("fedavg", n_clients=4, availability=0.0)
        eng.run_round()
        assert eng._server_updates > 0
        assert eng.evaluate(max_batches=1) == \
            eng.evaluate(max_batches=1, head="global")

    def test_local_eval_falls_back_when_nobody_feasible(self):
        eng = _engine("ssfl", n_clients=4)
        eng.state.fleet.feasible[:] = False
        acc = eng.evaluate(max_batches=1, head="local")
        assert 0.0 <= acc <= 1.0

    def test_hasfl_subcohorts_chain_server_moments(self):
        """Every same-depth batch sub-group must step the shared server
        branch: adamw's step counter equals local_steps x number of
        (depth, batch) groups."""
        eng = _engine("hasfl", n_clients=10, optimizer="adamw", lr=0.01,
                      local_steps=2)
        eng.run_round()
        fleet, strat = eng.state.fleet, eng.strategy
        n_groups = len({(int(d), int(b))
                        for d, b in zip(fleet.depths, strat._bs)})
        t = int(np.asarray(eng.state.opt_state["server"]["t"]))
        assert t == eng.local_steps * n_groups

"""Integration: the dry-run machinery on a small (2,4) mesh in a subprocess
(so the host-device-count flag never leaks into this test process)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.configs import base
    from repro.launch import steps as ST, sharding as SH
    from repro.roofline import analysis as RA

    cfg = base.get_reduced("{arch}").replace(
        dtype="float32", remat=True, microbatches=1)
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    shape = base.InputShape("t", {seq}, 4, "{kind}")
    p_shapes = ST.params_specs(cfg)
    p_specs = SH.param_pspecs(cfg, p_shapes, mesh)
    with mesh:
        if "{kind}" == "train":
            step, opt = ST.make_train_step(cfg)
            o_shapes = jax.eval_shape(opt.init, p_shapes)
            o_specs = {{"m": p_specs, "v": p_specs, "t": SH.P()}}
            b_shapes = ST.batch_specs(cfg, shape)
            b_specs = SH.batch_pspecs(cfg, shape, b_shapes, mesh)
            comp = jax.jit(step,
                in_shardings=SH.named(mesh, (p_specs, o_specs, b_specs)),
                out_shardings=SH.named(mesh, (p_specs, o_specs, None))
                ).lower(p_shapes, o_shapes, b_shapes).compile()
        else:
            step = ST.make_serve_step(cfg)
            c_shapes = ST.cache_specs(cfg, shape)
            c_specs = SH.cache_pspecs(cfg, c_shapes, mesh)
            t_shapes = ST.token_specs(cfg, shape)
            comp = jax.jit(step,
                in_shardings=SH.named(mesh, (p_specs, c_specs, SH.P())),
                out_shardings=SH.named(mesh, (None, c_specs))
                ).lower(p_shapes, c_shapes, t_shapes).compile()
    hlo = comp.as_text()
    coll = RA.collective_bytes(hlo)
    flops = RA.dot_flops(hlo)
    assert flops > 0
    # sharded training must communicate (grad sync at minimum)
    if "{kind}" == "train":
        assert coll["total"] > 0
    print("DRYRUN_SMALL_OK", int(coll["total"]), int(flops))
""")


def _run(arch, seq, kind):
    r = subprocess.run([sys.executable, "-c",
                        CODE.format(arch=arch, seq=seq, kind=kind)],
                       capture_output=True, text=True, cwd=ROOT, timeout=420)
    assert "DRYRUN_SMALL_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mixtral_8x7b",
                                  "mamba2_2_7b"])
def test_small_mesh_train_compiles(arch):
    _run(arch, 64, "train")


def test_small_mesh_decode_compiles():
    _run("hymba_1_5b", 64, "decode")

"""Parity pin for the device-resident round path (PR 3 tentpole).

The bucketed / scanned / device-gather execution refactor must be a
numerical no-op: these golden 2-round records were produced by the
PRE-refactor engine (commit 735bb12 — host-looped batches, one jit per
cohort size, per-client tree lists) on this exact setting, and the
refactored path must reproduce them within 1e-5. Together with the seed
goldens in ``test_engine_api.py`` (a different availability/fleet setting)
this pins every layer the refactor touched: batch-RNG order, kernel math,
masked pooled-gradient means, and masked aggregation.
"""
import numpy as np
import pytest

from repro.configs import base
from repro.federated import Engine

# Pre-refactor engine records: vit16_cifar reduced to n_layers=4/d_model=48/
# n_heads=4/head_dim=12/d_ff=96/image_size=16/n_classes=6, n_clients=6,
# seed=0, lr=0.3, local_steps=2, batch_size=8, availability=0.8.
PRE_REFACTOR_GOLDEN = {
    "ssfl": [{"loss": 1.7477002516768563, "comm_mb": 2.54, "time_s": 1.16},
             {"loss": 1.7418298603626192, "comm_mb": 5.17, "time_s": 2.31}],
    "sfl": [{"loss": 1.7646270036697387, "comm_mb": 2.08, "time_s": 1.04},
            {"loss": 1.7266807079315185, "comm_mb": 4.86, "time_s": 2.08}],
    "fedavg": [{"loss": 1.739494800567627, "comm_mb": 2.4, "time_s": 0.45},
               {"loss": 1.7335288524627686, "comm_mb": 5.41, "time_s": 0.9}],
}


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


@pytest.mark.parametrize("method", sorted(PRE_REFACTOR_GOLDEN))
def test_two_round_records_match_pre_refactor_engine(method):
    eng = Engine(_cfg(), 6, method, seed=0, lr=0.3, local_steps=2,
                 batch_size=8, availability=0.8)
    for want in PRE_REFACTOR_GOLDEN[method]:
        rec = eng.run_round()
        for k, v in want.items():
            assert rec[k] == pytest.approx(v, abs=1e-5), (method, k)


def test_exact_and_ladder_bucketing_agree():
    """Padding a cohort up to its bucket must be a numerical no-op: the
    same run under exact-size kernels (no padded slots) and under the
    default ladder (padded slots masked everywhere) produces the same
    model."""
    import jax
    mk = lambda b: Engine(_cfg(), 5, "ssfl", seed=0, lr=0.3, local_steps=2,
                          batch_size=8, availability=0.7, bucketing=b)
    a, b = mk("exact"), mk("ladder")
    for _ in range(2):
        ra, rb = a.run_round(), b.run_round()
        assert rb["loss"] == pytest.approx(ra["loss"], abs=1e-5)
    for x, y in zip(jax.tree.leaves(a.state.params),
                    jax.tree.leaves(b.state.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)

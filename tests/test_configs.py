import pytest

from repro.configs import base


def test_all_configs_load():
    for a in base.ARCH_IDS + base.EXTRA_ARCH_IDS:
        cfg = base.get_config(a)
        assert cfg.n_layers > 0 and cfg.d_model > 0


def test_reduced_constraints():
    # smoke variants must be: <=2 layers, d_model<=512, <=4 experts
    for a in base.ARCH_IDS + base.EXTRA_ARCH_IDS:
        r = base.get_reduced(a)
        assert r.n_layers <= 2, a
        assert r.d_model <= 512, a
        assert r.n_experts <= 4, a


def test_assigned_geometry_exact():
    # spot-check the assigned architecture table
    g = base.get_config("grok_1_314b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab, g.n_experts, g.top_k) == (64, 6144, 48, 8, 32768,
                                               131072, 8, 2)
    q = base.get_config("qwen2_5_3b")
    assert q.qkv_bias and (q.n_layers, q.n_kv_heads) == (36, 2)
    m = base.get_config("mamba2_2_7b")
    assert m.family == "ssm" and m.ssm_state == 128 and m.n_layers == 64
    ge = base.get_config("gemma_2b")
    assert ge.mlp == "geglu" and ge.resolved_head_dim == 256 \
        and ge.n_kv_heads == 1
    h = base.get_config("hymba_1_5b")
    assert h.family == "hybrid" and h.ssm_state == 16 and h.n_heads == 25
    w = base.get_config("whisper_small")
    assert w.is_encdec and w.n_enc_layers == 12 and w.norm == "layernorm"
    v = base.get_config("internvl2_2b")
    assert v.family == "vlm" and v.vocab == 92553


def test_combo_matrix():
    combos = base.all_combos()
    # 10 archs x 4 shapes minus the whisper long_500k skip
    assert len(combos) == 39
    assert base.skip_reason("whisper_small", "long_500k") is not None
    assert base.skip_reason("mamba2_2_7b", "long_500k") is None


def test_padded_vocab_shards():
    for a in base.ARCH_IDS:
        cfg = base.get_config(a)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


def _arr(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ------------------------------------------------------------- tpgf_fusion

@pytest.mark.parametrize("shape", [(7,), (130,), (33, 65), (4, 7, 13),
                                   (256, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_tpgf_fusion(shape, dtype):
    from repro.kernels.tpgf_fusion import ops as O, ref as R
    a, b = _arr(shape, dtype), _arr(shape, dtype)
    got = O.fuse_leaf(a, b, 0.3, 0.7)
    want = R.fuse(a, b, 0.3, 0.7)
    tol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_tpgf_fusion_tree_with_clip():
    from repro.kernels.tpgf_fusion import ops as O
    from repro.core import tpgf as T
    gc = {"a": _arr((17, 9), "float32"), "b": _arr((64,), "float32")}
    gs = {"a": _arr((17, 9), "float32"), "b": _arr((64,), "float32")}
    w = jnp.float32(0.4)
    got = O.fuse_tree(gc, gs, w, tau=0.5)
    clipped, _ = T.clip_by_global_l2(gc, 0.5)
    want = jax.tree.map(lambda c, s: w * c + (1 - w) * s, clipped, gs)
    jax.tree.map(lambda g, r: np.testing.assert_allclose(
        np.asarray(g), np.asarray(r), rtol=1e-4, atol=1e-6), got, want)


def test_sumsq_kernel():
    from repro.kernels.tpgf_fusion import kernel as K, ops as O
    x = _arr((1000,), "float32")
    t, _ = O._to_tiles(x)
    np.testing.assert_allclose(float(K.sumsq_2d(t)),
                               float(jnp.sum(x * x)), rtol=1e-5)


@pytest.mark.parametrize("T,shape", [(2, (1000,)), (3, (33, 65)),
                                     (4, (256, 128))])
def test_tier_sum_kernel(T, shape):
    """Cross-tier weighted accumulation (fuse_tiers' use_pallas path) vs
    the plain weighted sum, including a zero-weight tier."""
    from repro.kernels.tpgf_fusion import ops as O
    leaves = [_arr(shape, "float32") for _ in range(T)]
    w = [jnp.float32(x) for x in RNG.uniform(0.0, 2.0, T)]
    w[-1] = jnp.float32(0.0)
    got = O.tier_sum_leaf(leaves, w)
    want = sum(wi * xi.astype(jnp.float32) for wi, xi in zip(w, leaves))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- layer_aggregate

@pytest.mark.parametrize("N,Lk,rest", [(3, 2, (40,)), (5, 4, (3, 90)),
                                       (2, 6, (512,)), (8, 3, (7, 11, 5))])
def test_layer_aggregate(N, Lk, rest):
    from repro.kernels.layer_aggregate import ops as O, ref as R
    c = _arr((N, Lk) + rest, "float32")
    ww = jnp.asarray(RNG.uniform(0, 1, (N, Lk)), jnp.float32)
    s = _arr((Lk,) + rest, "float32")
    got = O.aggregate_leaf(c, ww, s, 0.01)
    F = int(np.prod(rest))
    want = R.aggregate(c.reshape(N, Lk, F), ww, s.reshape(Lk, F),
                       0.01).reshape(s.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_layer_aggregate_presence_zero_weight():
    """ww=0 rows (absent layers) leave theta_bar at the server value."""
    from repro.kernels.layer_aggregate import ops as O
    c = _arr((3, 2, 128), "float32")
    ww = jnp.zeros((3, 2), jnp.float32)
    s = _arr((2, 128), "float32")
    got = O.aggregate_leaf(c, ww, s, 0.01)
    np.testing.assert_allclose(np.asarray(got), np.asarray(s), rtol=1e-5)


# --------------------------------------------------------- flash_attention

@pytest.mark.parametrize("B,S,H,K,hd,causal,win", [
    (2, 128, 4, 2, 32, True, 0),
    (1, 256, 4, 4, 64, True, 64),
    (2, 128, 8, 1, 32, True, 0),      # MQA
    (1, 128, 4, 2, 32, False, 0),
    (1, 256, 2, 2, 128, True, 128),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention(B, S, H, K, hd, causal, win, dtype):
    from repro.kernels.flash_attention import ops as O, ref as R
    q, k, v = (_arr((B, S, H, hd), dtype), _arr((B, S, K, hd), dtype),
               _arr((B, S, K, hd), dtype))
    got = O.flash_attention(q, k, v, causal=causal, window=win)
    want = R.flash_attention_ref(q, k, v, causal=causal, window=win)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_blockwise_attention_matches_ref():
    from repro.models.layers import blockwise_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q, k, v = (_arr((2, 512, 4, 32), "float32"),
               _arr((2, 512, 2, 32), "float32"),
               _arr((2, 512, 2, 32), "float32"))
    for causal, win in [(True, 0), (True, 100), (False, 0)]:
        got = blockwise_attention(q, k, v, causal=causal, window=win,
                                  bq=128, bk=128)
        want = flash_attention_ref(q, k, v, causal=causal, window=win)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-5)


# --------------------------------------------------------------- ssd_scan

@pytest.mark.parametrize("Bt,S,nh,hd,st,chunk", [
    (2, 256, 4, 32, 16, 128),
    (1, 128, 2, 64, 32, 64),
    (2, 64, 3, 32, 16, 64),
    (1, 512, 2, 32, 128, 128),
])
def test_ssd_scan(Bt, S, nh, hd, st, chunk):
    from repro.kernels.ssd_scan import ops as O, ref as R
    x = _arr((Bt, S, nh, hd), "float32")
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bt, S, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B = _arr((Bt, S, st), "float32")
    C = _arr((Bt, S, st), "float32")
    D = _arr((nh,), "float32")
    y, h = O.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    yr, hr = R.ssd_ref(x, dt, A, B, C, chunk=chunk)
    yr = yr + x * D[None, None, :, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_sequential_recurrence():
    """Chunked SSD == naive per-step recurrence (ground truth)."""
    from repro.kernels.ssd_scan import ops as O
    Bt, S, nh, hd, st = 1, 32, 2, 8, 4
    x = _arr((Bt, S, nh, hd), "float32")
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bt, S, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B = _arr((Bt, S, st), "float32")
    C = _arr((Bt, S, st), "float32")
    y, hf = O.ssd_scan(x, dt, A, B, C, chunk=16)
    h = np.zeros((Bt, nh, hd, st), np.float32)
    ys = []
    xn, dtn, Bn, Cn, An = map(np.asarray, (x, dt, B, C, A))
    for t in range(S):
        a = np.exp(dtn[:, t] * An)                       # [Bt,nh]
        u = xn[:, t] * dtn[:, t][..., None]              # [Bt,nh,hd]
        h = h * a[:, :, None, None] + np.einsum("bhd,bs->bhds", u, Bn[:, t])
        ys.append(np.einsum("bs,bhds->bhd", Cn[:, t], h))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-4, atol=1e-4)

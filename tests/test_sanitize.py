"""Checkify sanitizer mode (``Engine(sanitize=True)``): injected NaNs are
caught and attributed to the offending bucket slot, out-of-bounds batch
gathers trip the ``guard_gather`` user check, healthy sanitized rounds are
bit-exact with the normal path, and ``sanitize=False`` keeps the
seed-golden parity untouched. The forced-8-device mesh smoke runs through
the ``_multidevice_child.py`` subprocess pattern so the device-count flag
never leaks into this process."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import base
from repro.federated import Engine
from repro.federated.bucketing import (SlotSanitizerError, kernel_compiles)

ROOT = os.path.join(os.path.dirname(__file__), "..")
CHILD = os.path.join(os.path.dirname(__file__), "_multidevice_child.py")

# the seed-golden setting from test_engine_api.py (2 rounds, ssfl)
GOLDEN_SSFL = [1.733882517260262, 1.6497505946508355]


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


def _engine(method="ssfl", **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 2)
    kw.setdefault("batch_size", 8)
    return Engine(_cfg(), kw.pop("n_clients", 5), method, **kw)


class TestNaNAttribution:
    def test_injected_nan_is_caught_with_the_offending_slot(self):
        # fedavg runs ONE cohort of all clients at availability 1.0, so
        # bucket slot i holds client i: poisoning client 3's shard must
        # come back as exactly slot 3.
        eng = _engine("fedavg", sanitize=True)
        eng.data["clients"][3].images[:] = np.nan
        with pytest.raises(SlotSanitizerError) as exc:
            eng.run_round()
        assert exc.value.slots == (3,)
        assert "nan" in str(exc.value).lower()
        assert "step_kernel" in str(exc.value)

    def test_split_strategy_reports_a_slot_too(self):
        eng = _engine("ssfl", n_clients=4, local_steps=1, batch_size=4,
                      sanitize=True)
        eng.data["clients"][2].images[:] = np.nan
        with pytest.raises(SlotSanitizerError) as exc:
            eng.run_round()
        assert exc.value.slots   # depth-grouped cohorts: slot != client id
        assert "cohort_kernel" in str(exc.value)

    def test_unsanitized_run_propagates_silently(self):
        # the hazard the sanitizer exists for: same poison, default mode,
        # the round completes and the NaN just drifts into the loss
        eng = _engine("ssfl", n_clients=4, local_steps=1, batch_size=4)
        eng.data["clients"][2].images[:] = np.nan
        assert np.isnan(eng.run_round()["loss"])


class TestOOBGather:
    def test_oob_batch_index_trips_guard_gather(self):
        eng = _engine("ssfl", n_clients=4, local_steps=1, batch_size=4,
                      sanitize=True)
        orig = eng._sample_indices

        def poisoned(ids, steps, batch_size=None):
            out = orig(ids, steps, batch_size)
            out[0, 0, 0] = 10_000_000   # way past the flat dataset
            return out

        eng._sample_indices = poisoned
        with pytest.raises(SlotSanitizerError, match="out of bounds"):
            eng.run_round()

    def test_in_bounds_padded_slots_do_not_trip(self):
        # 3 of 4 clients in a 4-slot bucket: pad_rows fills the pad slot's
        # sample indices with 0 — in range, so the guard must stay quiet
        eng = _engine("ssfl", n_clients=3, local_steps=1, batch_size=4,
                      sanitize=True)
        assert np.isfinite(eng.run_round()["loss"])


class TestParity:
    def test_sanitize_false_matches_seed_goldens(self):
        eng = _engine("ssfl", availability=0.7, sanitize=False)
        for want in GOLDEN_SSFL:
            assert abs(eng.run_round()["loss"] - want) < 1e-5

    def test_sanitize_false_is_bitwise_the_default_engine(self):
        import jax
        a, b = _engine("ssfl"), _engine("ssfl", sanitize=False)
        for _ in range(2):
            ra, rb = a.run_round(), b.run_round()
            assert ra["loss"] == rb["loss"]
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_healthy_sanitized_rounds_match_bit_exact(self):
        # checkify only *observes*: instrumented kernels must produce the
        # identical floats, so sanitize=True is a free drop-in for debug
        a, b = _engine("ssfl"), _engine("ssfl", sanitize=True)
        for _ in range(2):
            assert a.run_round()["loss"] == b.run_round()["loss"]


class TestAccounting:
    def test_sanitized_variant_counts_as_compiles(self):
        before = kernel_compiles()
        eng = _engine("fedavg", n_clients=4, local_steps=1, batch_size=4,
                      sanitize=True)
        eng.run_round()
        fresh = kernel_compiles() - before
        assert fresh >= 1
        warm = kernel_compiles()
        eng.run_round()   # same (depth, bucket): cache must absorb it
        assert kernel_compiles() == warm


class TestMeshSmoke:
    def test_sanitize_on_forced_8_device_mesh(self):
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")
        r = subprocess.run([sys.executable, CHILD, "sanitize"],
                           capture_output=True, text=True, cwd=ROOT,
                           env=env, timeout=900)
        assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
        assert "SANITIZE_OK healthy_mesh_rounds" in r.stdout
        assert "SANITIZE_OK nan_caught_under_mesh" in r.stdout

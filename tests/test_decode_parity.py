"""Property: step-by-step decode must equal the teacher-forced forward."""
import jax
import numpy as np
import pytest

from repro.configs import base
from repro.configs.base import InputShape
from repro.models import decode as D
from repro.models import model as M

FAMS = ["llama3_2_3b", "mixtral_8x7b", "mamba2_2_7b", "hymba_1_5b",
        "whisper_small", "gemma_2b", "qwen2_5_3b", "internvl2_2b",
        "grok_1_314b", "internlm2_1_8b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forced(arch):
    cfg = base.get_reduced(arch).replace(sliding_window=0)
    S = 12
    npatch = cfg.n_patches if cfg.family == "vlm" else 0
    rng = jax.random.PRNGKey(2)
    params = M.init_params(cfg, rng)
    batch = M.make_dummy_batch(cfg, InputShape("t", S + npatch, 2, "prefill"),
                               rng)
    logits_full, _ = D.prefill(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 3]
    pre["labels"] = batch["labels"][:, :S - 3]
    lp, cache = D.prefill(cfg, params, pre, decode_budget=8)
    outs = [lp[:, -1]]
    for t in range(S - 3, S):
        lg, cache = D.decode_step(cfg, params, cache,
                                  batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    got = np.stack([np.asarray(o, np.float32) for o in outs[:-1]], 1)
    want = np.asarray(logits_full[:, S - 4 + npatch:S - 1 + npatch],
                      np.float32)
    denom = np.abs(want).max() + 1e-9
    assert np.max(np.abs(got - want)) / denom < 2e-3, arch


def test_rolling_window_cache_matches_windowed_attention():
    """Decode with a rolling W-slot cache == full attention restricted to
    the last W positions (mixtral's native sliding window)."""
    cfg = base.get_reduced("mixtral_8x7b")  # sliding_window=16 in reduced
    W = cfg.sliding_window
    S = 24  # > W so the buffer wraps
    rng = jax.random.PRNGKey(3)
    params = M.init_params(cfg, rng)
    batch = M.make_dummy_batch(cfg, InputShape("t", S, 1, "prefill"), rng)
    logits_full, _ = D.prefill(cfg, params, batch)   # windowed attention
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 4]
    pre["labels"] = batch["labels"][:, :S - 4]
    _, cache = D.prefill(cfg, params, pre)
    assert cache["k"].shape[2] == W                  # rolling buffer
    outs = []
    for t in range(S - 4, S):
        lg, cache = D.decode_step(cfg, params, cache,
                                  batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    got = np.stack([np.asarray(o, np.float32) for o in outs[:-1]], 1)
    want = np.asarray(logits_full[:, S - 4:S - 1], np.float32)
    assert np.max(np.abs(got - want)) / (np.abs(want).max() + 1e-9) < 2e-3

"""Strategy/Engine API tests: registry round-trip, numerical parity of the
single-code-path engine against the seed ``FederatedTrainer`` records, the
new scenario knobs (``sample_frac``, pluggable optimizer), and TrainState
checkpointing."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import base
from repro.federated import (Engine, FederatedTrainer, available_strategies,
                             get_strategy)
from repro.federated.strategies.base import Strategy

METHODS = ("ssfl", "sfl", "dfl", "fedavg")

# Golden 2-round records produced by the pre-refactor seed trainer
# (commit 11d6a28) on this exact setting: vit16_cifar reduced to
# n_layers=4/d_model=48/n_heads=4/head_dim=12/d_ff=96/image_size=16/
# n_classes=6, n_clients=5, seed=0, lr=0.3, local_steps=2, batch_size=8,
# availability=0.7. The engine must reproduce them within 1e-5.
SEED_GOLDEN = {
    "ssfl": [{"loss": 1.733882517260262, "comm_mb": 2.56, "time_s": 1.16},
             {"loss": 1.6497505946508355, "comm_mb": 5.02, "time_s": 2.33}],
    "sfl": [{"loss": 1.7448828220367432, "comm_mb": 2.08, "time_s": 1.17},
            {"loss": 1.7244073152542114, "comm_mb": 3.47, "time_s": 2.34}],
    "dfl": [{"loss": 1.744882845878601, "comm_mb": 2.08, "time_s": 1.17},
            {"loss": 1.7244112968444825, "comm_mb": 3.47, "time_s": 2.34}],
    "fedavg": [{"loss": 1.6937156915664673, "comm_mb": 1.8, "time_s": 0.41},
               {"loss": 1.6152817010879517, "comm_mb": 3.01, "time_s": 0.83}],
}


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


def _engine(method="ssfl", **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 2)
    kw.setdefault("batch_size", 8)
    return Engine(_cfg(), kw.pop("n_clients", 5), method, **kw)


class TestRegistry:
    @pytest.mark.parametrize("name", METHODS)
    def test_round_trip(self, name):
        strat = get_strategy(name)
        assert isinstance(strat, Strategy)
        assert strat.name == name

    def test_all_builtins_listed(self):
        assert set(METHODS) <= set(available_strategies())

    def test_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown strategy"):
            get_strategy("no-such-method")


class TestSeedParity:
    @pytest.mark.parametrize("method", METHODS)
    def test_two_round_records_match_seed(self, method):
        """The seed-shim constructor path must reproduce the seed trainer's
        per-round (loss, comm_mb, time_s) on a fixed seed."""
        tr = FederatedTrainer(_cfg(), n_clients=5, method=method, seed=0,
                              lr=0.3, local_steps=2, batch_size=8,
                              availability=0.7)
        for want in SEED_GOLDEN[method]:
            rec = tr.run_round()
            for k, v in want.items():
                assert rec[k] == pytest.approx(v, abs=1e-5), (method, k)


class TestScenarioKnobs:
    def test_sample_frac_draws_subset(self):
        eng = _engine(n_clients=8, sample_frac=0.5)
        mask = eng._draw_participants()
        assert mask.sum() == 4
        # full participation consumes no sampling randomness
        full = _engine(n_clients=8)
        assert full._draw_participants().all()

    def test_sample_frac_round_trains_only_sampled(self):
        eng = _engine(n_clients=8, sample_frac=0.5)
        # local heads are ONE stacked tree with a leading [N] client axis
        before = np.asarray(jax.tree.leaves(eng.state.local_heads)[0]).copy()
        rec = eng.run_round()
        assert np.isfinite(rec["loss"])
        after = np.asarray(jax.tree.leaves(eng.state.local_heads)[0])
        changed = [not np.allclose(before[i], after[i])
                   for i in range(eng.state.n_clients)]
        # exactly the sampled half trained their phi_i
        assert 0 < sum(changed) <= 4

    def test_sample_frac_cheaper_than_full(self):
        full = _engine(n_clients=8).run_round()
        half = _engine(n_clients=8, sample_frac=0.5).run_round()
        assert half["comm_mb"] < full["comm_mb"]

    @pytest.mark.parametrize("opt", ["sgd_momentum", "adamw"])
    def test_optimizer_hook(self, opt):
        eng = _engine(n_clients=4, optimizer=opt, local_steps=2, lr=0.05)
        rec = eng.run_round()
        assert np.isfinite(rec["loss"])

    def test_builder(self):
        eng = (Engine.builder(_cfg())
               .clients(4, availability=0.9, sample_frac=1.0)
               .strategy("ssfl")
               .optimizer("sgd", lr=0.3)
               .rounds(local_steps=1, batch_size=8, seed=1)
               .build())
        assert np.isfinite(eng.run_round()["loss"])


class TestTrainState:
    def test_is_pytree(self):
        eng = _engine(n_clients=3)
        leaves = jax.tree.leaves(eng.state)
        assert len(leaves) > 0
        doubled = jax.tree.map(lambda x: x * 2, eng.state)
        assert doubled.round_idx == eng.state.round_idx

    def test_checkpoint_round_trip(self):
        eng = _engine(n_clients=3, local_steps=1)
        eng.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "state")
            eng.state.save(path)
            other = _engine(n_clients=3, local_steps=1, seed=4)
            other.state.restore(path)
        assert other.state.round_idx == 1
        for a, b in zip(jax.tree.leaves(eng.state.params),
                        jax.tree.leaves(other.state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_restores_pre_stacking_checkpoint(self):
        """PR-2-era checkpoints stored local_heads as one subtree per
        client index; restore must detect the layout and stack it."""
        from repro.checkpoint import save_checkpoint
        eng = _engine(n_clients=3, local_steps=1)
        eng.run_round()
        legacy_heads = {str(i): eng.state.head_for(i) for i in range(3)}
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "legacy")
            save_checkpoint(path, {"params": eng.state.params,
                                   "local_heads": legacy_heads,
                                   "opt_state": eng.state.opt_state},
                            step=1, meta={})
            other = _engine(n_clients=3, local_steps=1, seed=4)
            other.state.restore(path)
        for a, b in zip(jax.tree.leaves(eng.state.local_heads),
                        jax.tree.leaves(other.state.local_heads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


class TestLegacyCheckpointFormats:
    def test_engine_restore_from_legacy_per_index_checkpoint(self):
        """End-to-end regression for the stacked-head manifest migration:
        an ACTUAL legacy-format checkpoint on disk (``local_heads/<i>/...``
        subtrees, 11 clients so multi-digit index keys are exercised) must
        restore through ``Engine.restore`` and continue bit-identically to
        the uninterrupted run."""
        from repro.checkpoint import load_checkpoint, save_checkpoint
        mk = lambda: _engine(n_clients=11, local_steps=1, optimizer="adamw",
                             lr=0.01, availability=0.7)
        a = mk()
        a.run_round()
        a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            b = mk()
            b.run_round()
            b.save(os.path.join(tmp, "modern"))
            # rewrite the modern stacked checkpoint in the PR-2 layout:
            # one local_heads subtree per client index
            tree, manifest = load_checkpoint(os.path.join(tmp, "modern"))
            tree["local_heads"] = {
                str(i): jax.tree.map(lambda x, i=i: x[i],
                                     tree["local_heads"])
                for i in range(11)}
            save_checkpoint(os.path.join(tmp, "legacy"), tree,
                            step=manifest["step"], meta=manifest["meta"])
            c = mk()
            c.restore(os.path.join(tmp, "legacy"))
            assert c.state.round_idx == 1
            c.run_round()
        for x, y in zip(jax.tree.leaves((a.state.params,
                                         a.state.local_heads)),
                        jax.tree.leaves((c.state.params,
                                         c.state.local_heads))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestCommCostSignatureProbe:
    def test_new_hook_accepts_ids(self):
        eng = _engine("ssfl", n_clients=3)
        assert eng._comm_cost_takes_ids() is True

    def test_legacy_three_arg_hook_still_works(self):
        """A strategy written against the PR-1 protocol — no ``ids``
        parameter — must run end-to-end through the probed fallback."""
        from repro.federated.strategies.ssfl import SuperSFL

        class LegacyCost(SuperSFL):
            def comm_cost(self, engine, d, available):
                return (1000, 4) if available else (0, 4)

        eng = Engine(_cfg(), 3, LegacyCost(), seed=0, lr=0.3,
                     local_steps=1, batch_size=8, availability=1.0)
        assert eng._comm_cost_takes_ids() is False
        rec = eng.run_round()
        assert np.isfinite(rec["loss"])
        assert sum(r.comm_bytes for r in eng.accountant.rounds) == 3 * 1000
        assert sum(r.n_messages for r in eng.accountant.rounds) == 3 * 4

    def test_hasfl_per_id_pricing_matches_hand_computed(self):
        """3-client example, tuned batches pinned to (4, 8, 16): the
        ids-aware hook must price each client's smashed traffic at its OWN
        batch, the legacy call at the cohort mean."""
        from repro.core import supernet as SN
        eng = _engine("hasfl", n_clients=3, local_steps=2)
        strat = eng.strategy
        strat._bs = np.array([4, 8, 16])
        eng.state.fleet.depths[:] = 2
        d = 2
        pbytes = SN.client_param_bytes(eng.cfg, eng.state.params, d)
        per_tok = eng.tokens_per_sample() * eng.cfg.d_model * 4
        ids = np.array([0, 2])
        nbytes, nmsg = strat.comm_cost(eng, d, True, ids=ids)
        want = [2 * pbytes + eng.local_steps * 2 * b * per_tok
                for b in (4, 16)]
        np.testing.assert_array_equal(nbytes, want)
        np.testing.assert_array_equal(nmsg, [2 + 2 * eng.local_steps] * 2)
        # unavailable: only the parameter sync moves
        nbytes, _ = strat.comm_cost(eng, d, False, ids=ids)
        np.testing.assert_array_equal(nbytes, [2 * pbytes] * 2)
        # legacy (no ids) call: fleet-mean batch for this depth = 28/3
        scalar_bytes, msgs = strat.comm_cost(eng, d, True)
        assert scalar_bytes == 2 * pbytes + eng.local_steps * 2 * int(
            (28 / 3) * per_tok)
        assert msgs == 2 + 2 * eng.local_steps

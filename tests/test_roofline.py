"""Calibration tests for the roofline HLO parsers (see analysis.py docs)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline import analysis as RA

HLO_SNIPPET = """\
HloModule test

%region_body.1 (arg: (s32[], f32[64,512])) -> (s32[], f32[64,512]) {
  %p = f32[64,512]{1,0} parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%p), dimensions={0}
  ROOT %t = (s32[], f32[64,512]) tuple(%p, %ag)
}

%region_cond.2 (arg: (s32[], f32[64,512])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%c, %c), direction=LT
}

ENTRY %main.3 (x: f32[64,512]) -> f32[64,512] {
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%add
  %w = (s32[], f32[64,512]) while(%tup), condition=%region_cond.2, body=%region_body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %r = f32[64,512]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert RA._shape_bytes("f32[64,512]") == 64 * 512 * 4
    assert RA._shape_bytes("bf16[2,3,4]") == 24 * 2
    assert RA._shape_bytes("pred[7]") == 7


def test_collective_bytes_trip_corrected():
    out = RA.collective_bytes(HLO_SNIPPET)
    # all-reduce outside loop: 128*256*4 bytes * wire factor 2
    assert out["all-reduce"] == 128 * 256 * 4 * 2
    # all-gather inside while body: 64*512*4 * 12 trips
    assert out["all-gather"] == 64 * 512 * 4 * 12


def test_dot_flops_scan_calibration():
    """End-to-end: a 10-iteration scan of a 64x512x512 matmul must report
    exactly 10x the single-matmul FLOPs (this is the property jax's own
    cost_analysis does NOT have — it counts loop bodies once)."""
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.roofline import analysis as RA
        def f(w, x):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=10)
            return h
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 512), jnp.float32)
        hlo = jax.jit(f).lower(w, x).compile().as_text()
        got = RA.dot_flops(hlo)
        want = 10 * 2 * 64 * 512 * 512
        assert abs(got / want - 1) < 0.01, (got, want)
        cost = jax.jit(f).lower(w, x).compile().cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        # document the calibration fact itself:
        assert abs(cost["flops"] / (want / 10) - 1) < 0.01
        print("CALIBRATION_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "CALIBRATION_OK" in r.stdout, r.stderr[-2000:]


def test_model_flops_rules():
    from repro.configs import base
    cfg = base.get_config("llama3_2_3b")
    shape = base.INPUT_SHAPES["train_4k"]
    n = 3_000_000_000
    assert RA.model_flops(cfg, shape, n, n) == 6.0 * n * 256 * 4096
    dshape = base.INPUT_SHAPES["decode_32k"]
    assert RA.model_flops(cfg, dshape, n, n) == 2.0 * n * 128


def test_active_params_moe():
    from repro.configs import base
    cfg = base.get_config("mixtral_8x7b")
    n = 46_700_000_000
    a = RA.active_params(cfg, n)
    # top-2 of 8 experts: active well under a third of total
    assert n * 0.2 < a < n * 0.45

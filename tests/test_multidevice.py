"""Multi-device fleet execution (PR 4 tentpole): the bucket kernels run
through ``jax.shard_map`` over the fleet/client axis and must be
numerically equivalent to the replicated path — per-seed 2-round parity
for every strategy, bit-exact frozen-server / resume invariants, and the
bounded-compile property, all on a *forced* 8-device host.

Subprocess pattern from test_dryrun_small.py: each test spawns
``tests/_multidevice_child.py`` with the device-count flag set in the
child's environment only, so it never leaks into this process (see
conftest.py). In-process tests cover the single-device / non-dividing
fallbacks, which need no mesh."""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
CHILD = os.path.join(os.path.dirname(__file__), "_multidevice_child.py")


def _run(*args, devices=8):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, CHILD] + [str(a) for a in args],
                       capture_output=True, text=True, cwd=ROOT, env=env,
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


class TestShardedParity:
    """Sharded == replicated, per seed, for every registered strategy
    (grouped into a few children to amortize jax startup)."""

    @pytest.mark.parametrize("group", [("ssfl", "hasfl"), ("sfl", "dfl"),
                                       ("fedavg", "fedavgm", "unstable")],
                             ids=lambda g: "+".join(g))
    def test_two_round_parity_8dev(self, group):
        out = _run("parity", 8, *group)
        for method in group:
            assert f"PARITY_OK {method}" in out, out

    def test_mesh_that_does_not_divide_the_fleet(self):
        """3 shards, 13 clients: buckets pad to whole slots per shard,
        head storage falls back to replication, parity still holds."""
        out = _run("parity", 3, "ssfl")
        assert "PARITY_OK ssfl" in out, out

    def test_width_heterogeneous_cohort_parity_8dev(self):
        """A width-laddered fleet ((0.5, 1.0) tiers) splits cohorts into
        (depth, width) sub-groups — sharded must still equal replicated."""
        out = _run("widthparity")
        assert "WIDTHPARITY_OK ssfl" in out, out


class TestShardedInvariants:
    def test_frozen_server_and_resume_bit_exact(self):
        out = _run("invariants")
        assert "INVARIANTS_OK frozen_server" in out, out
        assert "INVARIANTS_OK resume" in out, out

    def test_cross_tier_fused_cohort(self):
        """Cross-tier TPGF fusion (the ``cross_tier="fused"`` default) on
        the forced-8-device mesh: mixed-width sharded == replicated
        2-round parity, and the frozen-server / adamw-resume invariants
        stay bit-exact when the server update is the fused one."""
        out = _run("crosstier")
        assert "CROSSTIER_OK parity" in out, out
        assert "CROSSTIER_OK frozen_server" in out, out
        assert "CROSSTIER_OK resume" in out, out


class TestShardedCompileCount:
    def test_compiles_o_depths_x_buckets(self):
        out = _run("compiles")
        assert "COMPILES_OK" in out, out


class TestFallbacks:
    """No multi-device host needed: the sharded dispatch must degrade
    cleanly to the replicated kernels."""

    def _engine(self, **kw):
        from repro.configs import base
        from repro.federated import Engine
        cfg = base.get_reduced("vit16_cifar").replace(
            n_layers=3, d_model=24, n_heads=2, n_kv_heads=2, head_dim=12,
            d_ff=48, image_size=16, n_classes=6)
        kw.setdefault("seed", 0)
        kw.setdefault("lr", 0.3)
        kw.setdefault("local_steps", 1)
        kw.setdefault("batch_size", 4)
        return Engine(cfg, kw.pop("n_clients", 4), "ssfl", **kw)

    def test_single_device_fleet_mesh_runs_replicated(self):
        import jax
        from repro.federated.bucketing import FleetKernel
        from repro.federated.strategies.ssfl import cohort_kernel
        from repro.launch.mesh import make_fleet_mesh
        eng = self._engine(mesh=make_fleet_mesh(1))
        assert eng.fleet_shards == 1
        assert isinstance(cohort_kernel, FleetKernel)
        # extent-1 mesh: the dispatch hands back the replicated kernel
        assert eng.kernel_fn(cohort_kernel, 8) is cohort_kernel
        assert np.isfinite(eng.run_round()["loss"])
        head = jax.tree.leaves(eng.state.local_heads)[0]
        assert head.sharding.spec[0] == ("data",)

    def test_non_dividing_bucket_falls_back(self):
        """An explicit ladder whose entry resists the shard rounding can
        never reach shard_map: kernel_fn hands back the replicated jit."""
        from repro.federated.strategies.ssfl import cohort_kernel
        from repro.launch.mesh import make_abstract_mesh
        eng = self._engine()
        eng.mesh = make_abstract_mesh((8,), ("data",))
        assert eng.fleet_shards == 8
        assert eng.kernel_fn(cohort_kernel, 12) is cohort_kernel
        # dividing buckets would dispatch to a per-mesh sharded variant
        assert eng.bucket_for(3) == 8

    def test_bucket_rounds_to_whole_slots_per_shard(self):
        from repro.federated.bucketing import bucket_size
        assert bucket_size(5, multiple_of=8) == 8
        assert bucket_size(9, multiple_of=8) == 16
        assert bucket_size(17, multiple_of=8) == 32   # ladder entry 32
        assert bucket_size(5, (), multiple_of=8) == 8   # exact ladder
        assert bucket_size(9, (3, 9), multiple_of=3) == 9
        assert bucket_size(4, (3, 9), multiple_of=8) == 16

"""End-to-end behaviour tests for the SuperSFL system (paper semantics)."""
import jax
import numpy as np
import pytest

from repro.configs import base
from repro.federated.round import FederatedTrainer


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


def _trainer(method, **kw):
    kw.setdefault("n_clients", 6)
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 2)
    kw.setdefault("batch_size", 24)
    return FederatedTrainer(_cfg(), method=method, **kw)


class TestSuperSFLSystem:
    def test_ssfl_learns_above_chance(self):
        tr = _trainer("ssfl")
        acc0 = tr.evaluate()
        for _ in range(8):
            rec = tr.run_round()
        acc = tr.evaluate()
        assert acc > max(acc0, 1.0 / 6) + 0.15, (acc0, acc)
        assert rec["comm_mb"] > 0 and rec["time_s"] > 0

    def test_depth_allocation_heterogeneous(self):
        tr = _trainer("ssfl")
        assert len(set(tr.fleet.depths.tolist())) > 1
        assert tr.fleet.depths.min() >= 1
        assert tr.fleet.depths.max() <= _cfg().n_layers - 1

    def test_serverless_training_still_learns(self):
        """Paper Table III, 0% row: availability=0 must not collapse."""
        tr = _trainer("ssfl", availability=0.0)
        for _ in range(8):
            tr.run_round()
        assert tr.evaluate() > 1.0 / 6 + 0.1

    def test_ssfl_comm_cheaper_than_sfl_per_round(self):
        """SSFL ships subnetworks; SFL re-syncs the full model."""
        t1 = _trainer("ssfl")
        t2 = _trainer("sfl")
        r1 = t1.run_round()
        r2 = t2.run_round()
        assert r1["comm_mb"] < r2["comm_mb"]

    def test_sfl_excludes_infeasible_clients(self):
        cfg = _cfg()
        tr = FederatedTrainer(cfg, n_clients=24, method="sfl", seed=3,
                              lr=0.3, local_steps=1, batch_size=8)
        # rigid split = mid-stack; clients with Eq.1 capacity below it are out
        assert (~tr.fleet.feasible).sum() >= 1
        ids = np.concatenate(list(tr.fleet.cohorts().values()))
        assert set(ids) == set(np.where(tr.fleet.feasible)[0])

    def test_local_heads_stay_local(self):
        """phi_i is never aggregated (paper §II-D)."""
        tr = _trainer("ssfl")
        before = [np.asarray(jax.tree.leaves(h)[0]).copy()
                  for h in tr.local_heads]
        tr.run_round()
        after = [np.asarray(jax.tree.leaves(h)[0]) for h in tr.local_heads]
        # heads changed per-client (trained locally)...
        changed = [not np.allclose(b, a) for b, a in zip(before, after)]
        assert any(changed)
        # ...and are NOT all identical to each other (no sync happened)
        flat = [a.ravel() for a in after]
        assert not all(np.allclose(flat[0], f) for f in flat[1:])

    def test_all_methods_run_one_round(self):
        for method in ("ssfl", "sfl", "dfl", "fedavg"):
            tr = _trainer(method)
            rec = tr.run_round()
            assert np.isfinite(rec["loss"]), method

    def test_tpgf_ablation_variants_run(self):
        for variant in ("full", "no_loss", "no_depth", "equal"):
            cfg = _cfg().replace(tpgf_variant=variant)
            tr = FederatedTrainer(cfg, n_clients=4, method="ssfl", seed=1,
                                  lr=0.3, local_steps=1, batch_size=16)
            rec = tr.run_round()
            assert np.isfinite(rec["loss"]), variant

"""Buffered-async aggregation tests (PR 5 tentpole): the capacity-K update
buffer, FedBuff-style buffered folding in the ``async_buffered`` strategy,
and the inherited invariants — frozen server, padded-slot contract, and
bit-identical buffer+moments checkpoint resume."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.federated import Engine, buffer as BUF
from repro.federated.strategies.async_buffered import BufferedAsync
from repro.optim import fedadam, fedyogi, get_optimizer, map_moments


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, image_size=16, n_classes=6)


def _engine(strategy, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 1)
    kw.setdefault("batch_size", 8)
    return Engine(_cfg(), kw.pop("n_clients", 6), strategy, **kw)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestBufferUnit:
    TEMPLATE = {"w": np.zeros(2, np.float32)}

    def test_init_shapes_and_fill(self):
        buf = BUF.init_buffer(self.TEMPLATE, 3)
        assert BUF.capacity_of(buf) == 3
        assert BUF.fill_count(buf) == 0
        assert buf["deltas"]["w"].shape == (3, 2)

    def test_push_fills_slots_in_order(self):
        buf = BUF.init_buffer(self.TEMPLATE, 3)
        buf = BUF.push(buf, {"w": jnp.asarray([1.0, 0.0])}, 2.0, 1.0, 0)
        buf = BUF.push(buf, {"w": jnp.asarray([0.0, 1.0])}, 1.0, 0.0, 1)
        assert BUF.fill_count(buf) == 2
        np.testing.assert_allclose(np.asarray(buf["weight"]), [2, 1, 0])
        np.testing.assert_allclose(np.asarray(buf["deltas"]["w"][0]),
                                   [1, 0])

    def test_flush_hand_computed_discount(self):
        """gamma=1, flush at round 2: entry A (weight 2, staleness 1,
        pushed round 0 -> age 2 -> eff 3) discounts to 2/(1+3) = 0.5;
        entry B (weight 1, staleness 0, pushed round 1 -> eff 1) to
        1/(1+1) = 0.5 -> equal normalized weights."""
        buf = BUF.init_buffer(self.TEMPLATE, 3)
        buf = BUF.push(buf, {"w": jnp.asarray([1.0, 0.0])}, 2.0, 1.0, 0)
        buf = BUF.push(buf, {"w": jnp.asarray([0.0, 1.0])}, 1.0, 0.0, 1)
        delta, fresh = BUF.flush(buf, gamma=1.0, round_idx=2)
        np.testing.assert_allclose(np.asarray(delta["w"]), [0.5, 0.5],
                                   rtol=1e-6)
        assert BUF.fill_count(fresh) == 0
        assert float(np.abs(np.asarray(fresh["deltas"]["w"])).sum()) == 0

    def test_gamma_zero_is_plain_weighted_mean(self):
        buf = BUF.init_buffer(self.TEMPLATE, 2)
        buf = BUF.push(buf, {"w": jnp.asarray([3.0, 0.0])}, 1.0, 9.0, 0)
        buf = BUF.push(buf, {"w": jnp.asarray([0.0, 3.0])}, 2.0, 0.0, 5)
        delta, _ = BUF.flush(buf, gamma=0.0, round_idx=7)
        np.testing.assert_allclose(np.asarray(delta["w"]), [1.0, 2.0],
                                   rtol=1e-6)

    def test_ring_overflow_drops_oldest(self):
        buf = BUF.init_buffer(self.TEMPLATE, 2)
        for i in range(3):
            buf = BUF.push(buf, {"w": jnp.asarray([float(i), 0.0])},
                           float(i + 1), 0.0, i)
        assert BUF.fill_count(buf) == 2
        np.testing.assert_allclose(np.asarray(buf["weight"]), [2, 3])
        np.testing.assert_allclose(np.asarray(buf["deltas"]["w"][:, 0]),
                                   [1, 2])

    def test_policies(self):
        buf = BUF.init_buffer(self.TEMPLATE, 2)
        assert not BUF.ready(buf, policy="count")
        assert not BUF.ready(buf, policy="round")
        buf = BUF.push(buf, {"w": jnp.asarray([1.0, 1.0])}, 1.0, 0.0, 3)
        assert BUF.ready(buf, policy="round")
        assert not BUF.ready(buf, policy="count")
        assert not BUF.ready(buf, policy="age", max_age=2, round_idx=4)
        assert BUF.ready(buf, policy="age", max_age=2, round_idx=5)
        buf = BUF.push(buf, {"w": jnp.asarray([1.0, 1.0])}, 1.0, 0.0, 4)
        assert BUF.ready(buf, policy="count")
        assert BUF.ready(buf, policy="age", max_age=99, round_idx=4)  # full

    def test_errors(self):
        buf = BUF.init_buffer(self.TEMPLATE, 2)
        with pytest.raises(ValueError):
            BUF.ready(buf, policy="never")
        with pytest.raises(ValueError):
            BUF.flush(buf)
        buf = BUF.push(buf, {"w": jnp.asarray([1.0, 1.0])}, 1.0, 0.0, 0)
        with pytest.raises(ValueError):
            BUF.ready(buf, policy="age", round_idx=1)   # max_age required


class TestFedOptUpdateRules:
    """FedAdam / FedYogi (Reddi et al.) update rules against hand-computed
    steps, plus the optimizer-state contract the strategies rely on.
    (The strategy-level resume tests live in ``test_scenarios.py`` next to
    the fedavgm ones.)"""

    B1, B2, LR, EPS = 0.9, 0.99, 0.1, 1e-3

    def _reference(self, kind, gs):
        """Explicit numpy transcription of the paper's update rules."""
        m = v = np.zeros_like(gs[0])
        out = []
        for g in gs:
            m = self.B1 * m + (1 - self.B1) * g
            if kind == "adam":
                v = self.B2 * v + (1 - self.B2) * g * g
            else:   # yogi
                v = v - (1 - self.B2) * g * g * np.sign(v - g * g)
            out.append(-self.LR * m / (np.sqrt(v) + self.EPS))
        return out

    @pytest.mark.parametrize("kind,make", [("adam", fedadam),
                                           ("yogi", fedyogi)])
    def test_hand_computed_two_steps(self, kind, make):
        # (no pair with g2^2 == (1-b2)-scaled v: Yogi's sign(v - g^2) is
        # discontinuous there and f32 vs f64 rounding could flip it)
        gs = [np.array([1.0, -2.0, 0.5]), np.array([0.2, 0.3, -4.0])]
        want = self._reference(kind, gs)
        opt = make(self.LR, b1=self.B1, b2=self.B2, eps=self.EPS)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        for g, w in zip(gs, want):
            upd, state = opt.update({"w": jnp.asarray(g)}, state, params)
            np.testing.assert_allclose(np.asarray(upd["w"]), w, rtol=1e-6)

    def test_yogi_forgets_variance_slower_than_adam(self):
        """After a large gradient then tiny ones, Yogi's additive rule
        keeps v higher than Adam's multiplicative decay — the FedYogi
        selling point under bursty pseudo-gradients."""
        gs = [np.array([4.0])] + [np.array([0.01])] * 20
        params = {"w": jnp.zeros(1)}
        states = {}
        for name, make in (("adam", fedadam), ("yogi", fedyogi)):
            opt = make(self.LR)
            s = opt.init(params)
            for g in gs:
                _, s = opt.update({"w": jnp.asarray(g)}, s, params)
            states[name] = float(np.asarray(s["v"]["w"])[0])
        assert states["yogi"] > states["adam"]

    def test_state_is_map_moments_sliceable(self):
        """m/v must be *moment entries* (mirror the params tree) so
        ``map_moments`` — and therefore every strategy slice/broadcast
        helper — treats them correctly."""
        params = {"a": jnp.zeros((4, 2)), "b": {"c": jnp.zeros(3)}}
        for make in (fedadam, fedyogi):
            state = make(0.1).init(params)
            sliced = map_moments(
                lambda t: jax.tree.map(lambda x: x[:1], t), state, params)
            assert sliced["m"]["a"].shape == (1, 2)
            assert sliced["v"]["b"]["c"].shape == (1,)

    def test_registry_resolution(self):
        assert get_optimizer("fedadam", 0.1) is get_optimizer("fedadam", 0.1)
        assert get_optimizer("fedyogi", 0.1).update is not None


class TestBufferedAsyncStrategy:
    def test_runs_end_to_end_and_flushes(self):
        strat = BufferedAsync(capacity=2)
        eng = _engine(strat, n_clients=8, local_steps=2)
        losses = [eng.run_round()["loss"] for _ in range(4)]
        assert any(np.isfinite(l) for l in losses)
        assert strat.flushes >= 1
        assert BUF.SLOT in eng.state.opt_state

    def test_params_frozen_between_flushes(self):
        """Until the buffer flushes, the globals must not move AT ALL —
        that is the async point (server compute continues, the model
        doesn't)."""
        strat = BufferedAsync(capacity=50)   # never fills in 3 rounds
        eng = _engine(strat, n_clients=6)
        p0 = jax.tree.map(lambda x: np.asarray(x).copy(), eng.state.params)
        for _ in range(3):
            eng.run_round()
        assert strat.flushes == 0
        assert BUF.fill_count(eng.state.opt_state[BUF.SLOT]) > 0
        _leaves_equal(p0, eng.state.params)

    def test_round_policy_single_cohort_recovers_unstable(self):
        """capacity=1 + flush-every-round + SGD(1.0) on a single-depth
        fleet is synchronous: one cohort -> one undiscounted entry ->
        params + (agg - params). Must match the ``unstable`` strategy up
        to that float round-trip."""
        mk = lambda s: _engine(s, n_clients=6, local_steps=2)
        a = mk("unstable")
        b = mk(BufferedAsync(capacity=1, policy="round", server_opt="sgd",
                             server_lr=1.0))
        for eng in (a, b):   # force ONE depth cohort (same edit both)
            eng.state.fleet.depths[:] = 2
            eng.state.fleet.feasible[:] = True
        for _ in range(2):
            a.run_round(), b.run_round()
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)

    @pytest.mark.parametrize("server_opt", ["sgd", "fedadam", "fedyogi"])
    def test_frozen_server_invariant(self, server_opt):
        """Server unreachable from round 0: across pushes AND flushes the
        server-side head and the kernel server moments stay bit-exact
        (cohort deltas are exactly zero on server-owned leaves, and zero
        pseudo-gradients are fixed points of every server optimizer from
        zero moments)."""
        strat = BufferedAsync(capacity=2, server_opt=server_opt,
                              server_lr=0.03)
        eng = _engine(strat, n_clients=5, optimizer="adamw", lr=0.05,
                      local_steps=2, availability=0.0)
        head = np.asarray(eng.state.params["head"]).copy()
        for _ in range(4):
            eng.run_round()
        assert strat.flushes >= 1
        np.testing.assert_array_equal(head,
                                      np.asarray(eng.state.params["head"]))
        # kernel server moments never stepped (freeze gate)
        assert int(np.asarray(eng.state.opt_state["server"]["t"])) == 0

    def test_padded_slot_contract(self):
        """Exact vs ladder bucketing must agree through the buffered path
        (the inherited kernels' padded slots stay numerical no-ops)."""
        mk = lambda b: _engine(
            BufferedAsync(capacity=2, server_opt="fedadam", server_lr=0.03),
            n_clients=5, local_steps=2, availability=0.7, bucketing=b)
        a, b = mk("exact"), mk("ladder")
        for _ in range(3):
            ra, rb = a.run_round(), b.run_round()
            if np.isfinite(ra["loss"]) or np.isfinite(rb["loss"]):
                assert rb["loss"] == pytest.approx(ra["loss"], abs=1e-5)
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=2e-5)

    @pytest.mark.parametrize("server_opt", ["fedadam", "fedyogi"])
    def test_buffer_and_moments_resume_bit_identical(self, server_opt):
        """3 uninterrupted rounds == 1 round + save + fresh engine +
        restore + 2 rounds, bit for bit — params, the buffered deltas and
        tags, the FedOpt moments, and the kernel server moments. The save
        lands mid-fill (capacity 5 > cohorts of round 1), so the restored
        run must replay the remaining pushes and the flush exactly."""
        mk = lambda: _engine(
            BufferedAsync(capacity=5, server_opt=server_opt,
                          server_lr=0.03),
            n_clients=6, optimizer="adamw", lr=0.01, local_steps=2,
            availability=0.7, sample_frac=0.8)
        a = mk()
        for _ in range(3):
            a.run_round()
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            b = mk()
            b.run_round()
            assert BUF.fill_count(b.state.opt_state[BUF.SLOT]) > 0
            b.save(path)
            c = mk()
            c.restore(path)
            assert c.state.round_idx == 1
            c.run_round()
            c.run_round()
        _leaves_equal(a.state.params, c.state.params)
        _leaves_equal(a.state.local_heads, c.state.local_heads)
        _leaves_equal(a.state.opt_state, c.state.opt_state)
        assert sorted(a.state.opt_state) == sorted(c.state.opt_state)

    def test_capacity_change_reinitializes_buffer(self):
        eng = _engine(BufferedAsync(capacity=4), n_clients=4)
        eng.run_round()
        assert BUF.capacity_of(eng.state.opt_state[BUF.SLOT]) == 4
        eng.strategy = BufferedAsync(capacity=2)
        eng._buffer_ok = None
        eng.run_round()
        assert BUF.capacity_of(eng.state.opt_state[BUF.SLOT]) == 2

    def test_entries_carry_their_own_server_view(self):
        """Each buffered entry's server movement must be its OWN cohort's
        — a round whose entries split across flushes must never re-apply
        another cohort's server delta. (Regression: entries used to share
        the round's cumulative streamed view, so the LAST cohort's head
        landed identically in every entry.)"""
        from repro.core.fault import AvailabilityModel
        strat = BufferedAsync(capacity=50)
        eng = _engine(strat, n_clients=6,
                      participation=AvailabilityModel(1.0))
        eng.run_round()
        buf = eng.state.opt_state[BUF.SLOT]
        n = BUF.fill_count(buf)
        assert n >= 2   # Eq.1 heterogeneity yields several depth cohorts
        heads = np.asarray(buf["deltas"]["head"][:n])
        assert np.abs(heads[0] - heads[1]).max() > 0

    def test_ctor_validation(self):
        with pytest.raises(ValueError):
            BufferedAsync(policy="sometimes")
        with pytest.raises(ValueError):
            BufferedAsync(policy="age")          # max_age required
        with pytest.raises(ValueError):
            BufferedAsync(capacity=0)
        BufferedAsync(policy="age", max_age=3)   # fine

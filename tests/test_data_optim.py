"""Data pipeline + optimizer + checkpoint tests (unit & property)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import (dirichlet_partition, make_federated_data,
                                  make_synthetic_images, synthetic_lm_batches)
from repro.optim import adamw, apply_updates, sgd_momentum

S = settings(max_examples=20, deadline=None)


class TestData:
    @S
    @given(st.integers(2, 24), st.sampled_from([0.1, 0.5, 5.0]))
    def test_partition_covers_everyone(self, n_clients, alpha):
        labels = np.random.default_rng(0).integers(0, 10, 1000)
        shards = dirichlet_partition(labels, n_clients, alpha, seed=1)
        assert len(shards) == n_clients
        assert all(len(s) >= 2 for s in shards)

    def test_smaller_alpha_is_more_skewed(self):
        labels = np.random.default_rng(0).integers(0, 10, 8000)

        def mean_entropy(alpha):
            shards = dirichlet_partition(labels, 12, alpha, seed=2)
            ents = []
            for s in shards:
                p = np.bincount(labels[s], minlength=10) / len(s)
                p = p[p > 0]
                ents.append(-(p * np.log(p)).sum())
            return np.mean(ents)

        assert mean_entropy(0.1) < mean_entropy(10.0)

    def test_train_test_share_prototypes(self):
        d = make_federated_data(4, seed=5)
        tr, te = d["dataset"], d["test"]
        # class-0 means should be close across splits (same prototypes)
        m_tr = tr.images[tr.labels == 0].mean(0)
        m_te = te.images[te.labels == 0].mean(0)
        assert np.abs(m_tr - m_te).mean() < 0.2

    def test_lm_stream_is_learnable_markov(self):
        batches = list(synthetic_lm_batches(64, 32, 4, 3, seed=0))
        assert len(batches) == 3
        b = batches[0]
        assert b["tokens"].shape == (4, 32)
        # labels are next tokens
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestOptim:
    @pytest.mark.parametrize("opt", [adamw(0.1), sgd_momentum(0.05)])
    def test_minimizes_quadratic(self, opt):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.tree.map(lambda w: 2 * w, params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_adamw_moment_dtype(self):
        p = {"w": jnp.ones((4,), jnp.bfloat16)}
        o32 = adamw(0.1).init(p)
        ob = adamw(0.1, moment_dtype=jnp.bfloat16).init(p)
        assert o32["m"]["w"].dtype == jnp.float32
        assert ob["m"]["w"].dtype == jnp.bfloat16

    def test_adamw_bf16_moments_still_learn(self):
        opt = adamw(0.1, moment_dtype=jnp.bfloat16)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.tree.map(lambda w: 2 * w, params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.abs(params["w"]).max()) < 0.05


class TestCheckpoint:
    def test_roundtrip(self):
        tree = {"a": {"b": np.arange(6).reshape(2, 3).astype(np.float32)},
                "c": np.asarray([1, 2], np.int32)}
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ck")
            save_checkpoint(path, tree, step=7, meta={"arch": "x"})
            loaded, manifest = load_checkpoint(path)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(loaded["a"]["b"], tree["a"]["b"])
        np.testing.assert_array_equal(loaded["c"], tree["c"])

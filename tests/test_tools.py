"""The repo's doc-gate tools must actually gate: a broken intra-repo link
and a failing doctest each force a nonzero exit, and healthy fixtures pass.
Both tools take an explicit root so the fixtures live in tmp_path and the
real repo docs stay out of scope here (CI's docs job covers those)."""
import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_links
import doctest_docs


def _repo(tmp_path: Path, docs: dict) -> Path:
    (tmp_path / "docs").mkdir()
    for rel, text in docs.items():
        (tmp_path / rel).write_text(text)
    return tmp_path


class TestCheckLinks:
    def test_broken_link_fails(self, tmp_path, capsys):
        root = _repo(tmp_path, {
            "README.md": "see [the docs](docs/guide.md) and "
                         "[gone](docs/missing.md)",
            "docs/guide.md": "back to [readme](../README.md)",
        })
        assert check_links.check(root) == 1
        out = capsys.readouterr().out
        assert "BROKEN LINK" in out and "docs/missing.md" in out
        assert "guide.md:1" not in out   # the good file is not blamed

    def test_healthy_links_pass(self, tmp_path, capsys):
        root = _repo(tmp_path, {
            "README.md": "see [the docs](docs/guide.md#anchor), "
                         "[external](https://example.com), "
                         "[mail](mailto:a@b.c), [in-page](#section)",
            "docs/guide.md": "relative [up](../README.md)",
        })
        assert check_links.check(root) == 0
        assert "OK" in capsys.readouterr().out

    def test_fragments_are_stripped_before_existence_check(self, tmp_path):
        root = _repo(tmp_path, {
            "README.md": "[frag](docs/guide.md#some-heading)",
            "docs/guide.md": "x",
        })
        assert check_links.check(root) == 0


class TestDoctestDocs:
    def test_failing_example_fails(self, tmp_path, capsys):
        root = _repo(tmp_path, {
            "README.md": "ok:\n\n>>> 1 + 1\n2\n",
            "docs/bad.md": "broken:\n\n>>> 2 + 2\n5\n",
        })
        assert doctest_docs.main(root) == 1
        assert "FAILED" in capsys.readouterr().out

    def test_healthy_examples_pass(self, tmp_path, capsys):
        root = _repo(tmp_path, {
            "README.md": ">>> sorted([3, 1, 2])\n[1, 2, 3]\n",
            "docs/guide.md": "prose only — ``` blocks without prompts "
                             "are not tests\n",
        })
        assert doctest_docs.main(root) == 0
        out = capsys.readouterr().out
        assert "all 1 doctest examples OK" in out

    def test_default_root_is_the_repo(self):
        # the no-arg form must keep gating the real docs (CI's invocation)
        repo_readme = Path(doctest_docs.__file__).resolve().parent.parent \
            / "README.md"
        assert repo_readme.exists()

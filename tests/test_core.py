"""Unit + hypothesis property tests for the paper's core (Eqs. 1, 3, 4, 6, 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import base
from repro.configs.base import InputShape
from repro.core import aggregation as AGG
from repro.core import allocation as AL
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.models import model as M

S = settings(max_examples=25, deadline=None)


# ------------------------------------------------------------------- Eq. (1)

class TestAllocation:
    def test_hand_computed(self):
        # alpha=0.5, beta=4: mem=2 -> 1; mem=16,lat=min -> 8+4=12; mid -> 6
        d = np.asarray(AL.allocate_depths([2, 16, 8], [200, 20, 110], 28))
        assert list(d) == [1, 12, 6]

    @S
    @given(st.lists(st.floats(0.1, 64.0), min_size=2, max_size=32),
           st.integers(2, 64))
    def test_bounds(self, mems, L):
        lats = np.linspace(20, 200, len(mems))
        d = np.asarray(AL.allocate_depths(mems, lats, L))
        assert (d >= 1).all() and (d <= L - 1).all()

    @S
    @given(st.floats(2.0, 15.0), st.floats(25.0, 195.0))
    def test_monotonic(self, mem, lat):
        # more memory => at least as deep; more latency => at most as deep
        base_d, hi_mem, hi_lat = np.asarray(AL.allocate_depths(
            [mem, mem + 1.0, mem], [lat, lat, min(lat + 5, 200)],
            64))
        assert hi_mem >= base_d
        assert hi_lat <= base_d


# --------------------------------------------------------------- Eqs. (3)-(4)

class TestTPGF:
    @S
    @given(st.floats(1e-4, 20.0), st.floats(1e-4, 20.0),
           st.integers(1, 63))
    def test_weight_bounds(self, lc, ls, d):
        L = 64
        w = float(T.tpgf_weight(lc, ls, d, L - d))
        # w_client in (0, depth_fraction)
        assert 0.0 < w < d / L + 1e-6

    def test_weight_monotonic_in_loss(self):
        # lower client loss -> higher client weight (reliability term)
        w_low = float(T.tpgf_weight(0.1, 1.0, 8, 24))
        w_high = float(T.tpgf_weight(1.0, 0.1, 8, 24))
        assert w_low > w_high

    def test_weight_monotonic_in_depth(self):
        w_shallow = float(T.tpgf_weight(1.0, 1.0, 2, 30))
        w_deep = float(T.tpgf_weight(1.0, 1.0, 16, 16))
        assert w_deep > w_shallow
        assert abs(w_deep - 0.25) < 1e-6  # 0.5 (depth) * 0.5 (equal loss)

    def test_clip_norm(self):
        tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
        clipped, norm = T.clip_by_global_l2(tree, 0.5)
        cn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                for x in jax.tree.leaves(clipped))))
        assert cn <= 0.5 + 1e-5
        # direction preserved
        ratio = float(clipped["a"][0] / clipped["b"][0])
        assert abs(ratio - 3.0 / 4.0) < 1e-5

    def test_clip_noop_below_threshold(self):
        tree = {"a": jnp.asarray([3e-3, 4e-3])}
        clipped, _ = T.clip_by_global_l2(tree, 0.5)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   [3e-3, 4e-3], rtol=1e-6)

    def test_fuse_equals_eq4(self):
        gc = {"x": jnp.asarray([1.0, 2.0])}
        gs = {"x": jnp.asarray([3.0, -2.0])}
        out = T.fuse_gradients(gc, gs, jnp.float32(0.25))
        np.testing.assert_allclose(
            np.asarray(out["x"]), 0.25 * np.asarray([1.0, 2.0])
            + 0.75 * np.asarray([3.0, -2.0]), rtol=1e-6)

    def test_fallback_equals_local_only(self):
        """server_available=False must reproduce the Algorithm-3 else-branch."""
        cfg = base.get_reduced("llama3_2_3b")
        rng = jax.random.PRNGKey(0)
        p = M.init_params(cfg, rng)
        b = M.make_dummy_batch(cfg, InputShape("t", 16, 2, "train"), rng)
        d = 1
        out = T.tpgf_grads(cfg, p, b, d,
                           server_available=jnp.asarray(False))
        g_ref, _ = T.local_only_grads(cfg, p, b, d)
        jax.tree.map(lambda a, r: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(r, np.float32),
            rtol=1e-4, atol=1e-6), out.grads, g_ref)


# --------------------------------------------------------------- Eqs. (6)-(8)

class TestAggregation:
    @S
    @given(st.lists(st.integers(1, 12), min_size=2, max_size=10))
    def test_weights_normalize(self, depths):
        losses = np.linspace(0.5, 2.0, len(depths))
        w = np.asarray(AGG.client_weights(depths, losses))
        assert (w > 0).all()
        # product of two normalized terms sums to <= 1
        assert w.sum() <= 1.0 + 1e-5

    def test_eq8_closed_form_minimizes_eq7(self):
        """theta_bar from Eq. 8 must minimize the Eq. 7 objective."""
        rng = np.random.default_rng(0)
        N, F = 4, 6
        thetas = rng.normal(size=(N, F)).astype(np.float32)
        theta_s = rng.normal(size=F).astype(np.float32)
        w = rng.uniform(0.1, 1.0, N).astype(np.float32)
        lam = 0.01

        def objective(t):
            return (np.sum(w[:, None] * (thetas - t) ** 2)
                    + lam * np.sum((theta_s - t) ** 2))

        closed = (np.einsum("n,nf->f", w, thetas) + lam * theta_s) \
            / (w.sum() + lam)
        # perturbations never improve
        for _ in range(20):
            pert = closed + rng.normal(scale=1e-2, size=F)
            assert objective(closed) <= objective(pert) + 1e-9

    def test_layer_alignment(self):
        """Layers beyond every client's depth stay at the server value; a
        layer held by exactly one client moves toward that client."""
        cfg = base.get_reduced("internlm2_1_8b")
        rng = jax.random.PRNGKey(0)
        g = M.init_params(cfg, rng)
        depths = [2, 1]
        trees = []
        for i, d in enumerate(depths):
            cp, _, _ = SN.split_params(
                cfg, M.init_params(cfg, jax.random.PRNGKey(i + 10)), d)
            trees.append(cp)
        stacked = AGG.stack_client_trees(cfg, trees, depths)
        new, w = AGG.aggregate(cfg, g, stacked, depths, [1.0, 1.0])
        wq_old = np.asarray(g["layers"]["attn"]["wq"], np.float32)
        wq_new = np.asarray(new["layers"]["attn"]["wq"], np.float32)
        # layer 1: only client 0 (depth 2) holds it -> changed
        assert np.abs(wq_new[1] - wq_old[1]).max() > 1e-4
        # lambda regularizer keeps it near a weighted blend incl. server
        c0 = np.asarray(trees[0]["layers"]["attn"]["wq"], np.float32)[1]
        w0 = float(np.asarray(w)[0])
        lam = cfg.agg_lambda
        expect = (w0 * c0 + lam * wq_old[1]) / (w0 + lam)
        np.testing.assert_allclose(wq_new[1], expect, rtol=1e-3, atol=1e-5)

    def test_fallback_clients_still_aggregate(self):
        """Paper §II-C: fallback-mode updates enter the next aggregation."""
        cfg = base.get_reduced("llama3_2_3b")
        g = M.init_params(cfg, jax.random.PRNGKey(0))
        cp, _, _ = SN.split_params(
            cfg, M.init_params(cfg, jax.random.PRNGKey(5)), 1)
        stacked = AGG.stack_client_trees(cfg, [cp], [1])
        new, _ = AGG.aggregate(cfg, g, stacked, [1], [1.0])
        assert np.abs(np.asarray(new["embed"], np.float32)
                      - np.asarray(g["embed"], np.float32)).max() > 1e-5


# ------------------------------------------------------------------ supernet

class TestSupernet:
    @pytest.mark.parametrize("arch", ["llama3_2_3b", "whisper_small",
                                      "vit16_cifar", "mamba2_2_7b"])
    def test_split_merge_roundtrip(self, arch):
        cfg = base.get_reduced(arch)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        for d in (1, cfg.split_stack_len - 1):
            c, s, l = SN.split_params(cfg, p, d)
            merged = SN.merge_params(cfg, c, s, l)
            assert set(merged) == set(p)
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), p, merged)

    def test_views_disjoint(self):
        cfg = base.get_reduced("qwen2_5_3b")
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        c, s, l = SN.split_params(cfg, p, 1)
        assert "local_head" in l and "local_head" not in c
        assert "unembed" in s and "embed" in c
        nc = jax.tree.leaves(c["layers"])[0].shape[0]
        ns = jax.tree.leaves(s["layers"])[0].shape[0]
        assert nc + ns == cfg.n_layers

    def test_client_bytes_monotonic(self):
        cfg = base.get_reduced("llama3_2_3b")
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        sizes = [SN.client_param_bytes(cfg, p, d) for d in (1, 2)]
        assert sizes[1] > sizes[0]

import os
import sys

# make `import repro` work regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# smoke tests and benches must see the real (1-device) CPU — the 512-device
# override belongs ONLY to repro.launch.dryrun (see system contract).
assert "--xla_force_host_platform_device_count=512" not in \
    os.environ.get("XLA_FLAGS", "")

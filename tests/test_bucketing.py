"""The bucketed device-resident execution layer (PR 3 tentpole).

Covers the padded-slot contract (zero gradient, zero loss weight, cannot
unfreeze the server), the sentinel-id scatter/gather boundary, the
batch-RNG equivalence of the on-device gather path, the bounded-compile
property (O(widths x buckets) kernel compiles under per-round cohort AND
depth churn — depth is a runtime kernel argument, the acceptance
criterion), and a 64-client smoke run per strategy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core import supernet as SN
from repro.data import synthetic as SYN
from repro.federated import Engine, bucketing as BK
from repro.federated.strategies import base as SB
from repro.federated.strategies import ssfl as SSFL
from repro.models import model as M
from repro.optim import get_optimizer


def _cfg(**kw):
    d = dict(n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
             d_ff=96, image_size=16, n_classes=6)
    d.update(kw)
    return base.get_reduced("vit16_cifar").replace(**d)


def _engine(method, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("lr", 0.3)
    kw.setdefault("local_steps", 1)
    kw.setdefault("batch_size", 4)
    cfg = kw.pop("cfg", None) or _cfg()
    return Engine(cfg, kw.pop("n_clients", 6), method, **kw)


class TestLadder:
    def test_bucket_size_rounds_up(self):
        assert [BK.bucket_size(n) for n in (1, 2, 3, 5, 8, 9, 33)] == \
            [1, 2, 4, 8, 8, 16, 64]

    def test_past_ladder_top_doubles(self):
        assert BK.bucket_size(65) == 128
        assert BK.bucket_size(200) == 256

    def test_exact_ladder_is_identity(self):
        for n in (1, 3, 5, 17):
            assert BK.bucket_size(n, ladder=()) == n

    def test_pad_ids_sentinel(self):
        out = BK.pad_ids(np.array([4, 7]), 4, n_clients=9)
        np.testing.assert_array_equal(out, [4, 7, 9, 9])

    def test_pad_helpers(self):
        a = BK.pad_rows(np.array([True, True]), 4, fill=False)
        np.testing.assert_array_equal(a, [True, True, False, False])
        idx = BK.pad_slot_axis(np.ones((2, 3, 5), np.int32), 4, axis=1)
        assert idx.shape == (2, 4, 5)
        assert (idx[:, 3] == 0).all()


class TestSentinelBoundary:
    def test_record_cohort_drops_padded_slots(self):
        """A padded slot's loss never lands in the fleet buffers — zero
        loss weight by construction."""
        ws = {"losses": jnp.zeros(3), "trained": jnp.zeros(3, bool)}
        SB.record_cohort(ws, jnp.asarray(BK.pad_ids(np.array([1]), 2, 3)),
                         jnp.array([1.5, 99.0]))
        np.testing.assert_allclose(np.asarray(ws["losses"]), [0, 1.5, 0])
        np.testing.assert_array_equal(np.asarray(ws["trained"]),
                                      [False, True, False])

    def test_scatter_rows_drops_sentinel(self):
        buf = {"w": jnp.zeros((3, 2))}
        ids = jnp.asarray(BK.pad_ids(np.array([2]), 2, 3))
        out = SB.scatter_rows(buf, ids, {"w": jnp.ones((2, 2))})
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   [[0, 0], [0, 0], [1, 1]])


class TestDeviceData:
    def test_gather_matches_host_sample_batch(self):
        """The device-resident index path draws the SAME batches, in the
        same stream order, as the legacy host path (the batch-RNG
        contract)."""
        data = SYN.make_federated_data(4, n_classes=6, image_size=8, seed=3)
        dd = SYN.as_device_data(data)
        ids = np.array([2, 0, 3])
        r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
        idx = dd.sample_indices(ids, steps=2, batch_size=5, rng=r1)
        for s in range(2):
            got = {"images": np.asarray(dd.images)[idx[s]],
                   "label": np.asarray(dd.labels)[idx[s]]}
            want = [data["clients"][i].sample_batch(5, r2) for i in ids]
            for j in range(len(ids)):
                np.testing.assert_array_equal(got["images"][j],
                                              want[j]["images"])
                np.testing.assert_array_equal(got["label"][j],
                                              want[j]["label"])


class TestPaddedSlotKernel:
    """Direct ssfl cohort_kernel checks of the padded-slot contract."""

    def _inputs(self, bucket, avail, valid, d=1, steps=1, bs=2):
        cfg = _cfg(n_layers=3, d_model=24, n_heads=2, n_kv_heads=2,
                   head_dim=12, d_ff=48)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        # runtime depth: the kernel takes FULL-L views plus d as an array
        client_p, server_p, local_p = SN.split_params(cfg, params, None)
        bc = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (bucket,) + x.shape), t)
        rng = np.random.default_rng(0)
        images = jnp.asarray(rng.normal(size=(16, cfg.image_size,
                                              cfg.image_size, 3)),
                             jnp.float32)
        labels = jnp.asarray(rng.integers(0, cfg.n_classes, 16), jnp.int32)
        idx = jnp.asarray(rng.integers(0, 16, (steps, bucket, bs)),
                          jnp.int32)
        opt = get_optimizer("sgd_momentum", 0.1)
        return (cfg, opt, steps, 1.0, jnp.int32(d), bc(client_p),
                bc(local_p), server_p, images, labels, idx,
                jnp.asarray(avail), jnp.asarray(valid),
                opt.init(server_p))

    def test_padded_slot_cannot_unfreeze_server(self):
        """avail=True on an INVALID slot must not step the server branch:
        the freeze gate is any(avail & valid), bit-exact."""
        args = self._inputs(2, avail=[False, True], valid=[True, False])
        server_p, srv_state = args[7], args[13]
        _, _, new_server, new_srv_state, _, _ = SSFL.cohort_kernel(*args)
        for a, b in zip(jax.tree.leaves(server_p),
                        jax.tree.leaves(new_server)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(srv_state),
                        jax.tree.leaves(new_srv_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_padded_slot_contributes_zero_gradient(self):
        """The pooled server update from a padded bucket equals the exact
        unpadded cohort's — padding is masked out of the gradient mean."""
        pad = self._inputs(4, avail=[True, True, False, False],
                           valid=[True, True, False, False])
        exact = self._inputs(2, avail=[True, True], valid=[True, True])
        # same per-slot batches for the two real slots
        pad = list(pad)
        pad[10] = jnp.concatenate([exact[10], exact[10]], axis=1)
        outs_p = SSFL.cohort_kernel(*pad)
        outs_e = SSFL.cohort_kernel(*exact)
        for a, b in zip(jax.tree.leaves(outs_e[2]),
                        jax.tree.leaves(outs_p[2])):   # server params
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        for a, b in zip(jax.tree.leaves(outs_e[0]),
                        jax.tree.leaves(outs_p[0])):   # client stacks
            np.testing.assert_allclose(np.asarray(a), np.asarray(b)[:2],
                                       atol=1e-6)


class TestBoundedCompile:
    def test_hasfl_64_clients_compiles_o_buckets(self):
        """ACCEPTANCE: a 5-round hasfl run at 64 clients with per-round
        cohort churn (sample_frac) compiles strictly fewer kernel programs
        than the number of distinct (depth, cohort-size) shapes the
        pre-refactor path would have specialized on. Depth is a RUNTIME
        argument, so the compiled key is (bucket, batch) — depth does not
        appear in it at all."""
        cfg = _cfg(n_layers=3, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64)   # unique cfg => cold jit keys
        eng = _engine("hasfl", cfg=cfg, n_clients=64, sample_frac=0.8,
                      batch_size=8)
        shapes = set()          # what the unbucketed path would jit on
        compiled_keys = set()   # what the bucketed path actually jits on
        strat, orig = eng.strategy, type(eng.strategy).cohorts

        def spy(self, engine, ctx):
            out = orig(self, engine, ctx)
            for d, ids in out.items():
                for b in np.unique(self._bs[ids]):
                    n = int((self._bs[ids] == b).sum())
                    shapes.add((d, n, int(b)))
                    compiled_keys.add((engine.bucket_for(n), int(b)))
            return out

        strat.cohorts = spy.__get__(strat)
        before = BK.kernel_compiles()
        for _ in range(5):
            assert np.isfinite(eng.run_round()["loss"])
        compiles = BK.kernel_compiles() - before
        assert len(shapes) > len(compiled_keys), shapes
        assert compiles < len(shapes)            # strictly fewer: acceptance
        assert compiles <= len(compiled_keys)    # O(buckets x batches)

    def test_width_tiers_compile_o_widths_buckets(self):
        """ACCEPTANCE: a 5-round width-laddered ssfl run at 64 clients with
        per-round cohort churn compiles at most O(widths x buckets) kernel
        programs — the static width joins the bucket in the compile key
        (depth rides as a runtime array), and re-grouping under churn must
        keep hitting the cache."""
        cfg = _cfg(n_layers=3, d_model=36, n_heads=2, n_kv_heads=2,
                   head_dim=18, d_ff=72)   # unique cfg => cold jit keys
        eng = _engine("ssfl", cfg=cfg, n_clients=64, sample_frac=0.8,
                      batch_size=8, width_tiers=(0.5, 1.0))
        assert (eng.state.fleet.widths < 1.0).any()
        widths, buckets, keys = set(), set(), set()
        strat, orig = eng.strategy, type(eng.strategy).cohorts

        def spy(self, engine, ctx):
            out = orig(self, engine, ctx)
            for d, ids in out.items():
                for w, gids in type(self)._width_groups(engine, ids):
                    b = engine.bucket_for(len(gids))
                    widths.add(w), buckets.add(b)
                    keys.add((w, b))
            return out

        strat.cohorts = spy.__get__(strat)
        before = BK.kernel_compiles()
        for _ in range(5):
            assert np.isfinite(eng.run_round()["loss"])
        compiles = BK.kernel_compiles() - before
        assert len(widths) == 2                  # the ladder actually split
        assert compiles <= len(keys)             # one program per live key
        assert compiles <= len(widths) * len(buckets)
        # and the cache stays warm: two more churning rounds, zero compiles
        before = BK.kernel_compiles()
        for _ in range(2):
            eng.run_round()
        assert BK.kernel_compiles() == before

    def test_ssfl_compile_count_stable_under_churn(self):
        """Round 3+ of a churning ssfl run must hit the kernel cache —
        zero new compiles once the bucket ladder is warm."""
        cfg = _cfg(n_layers=3, d_model=40, n_heads=2, n_kv_heads=2,
                   head_dim=20, d_ff=80)    # unique cfg => cold jit keys
        eng = _engine("ssfl", cfg=cfg, n_clients=16, sample_frac=0.6)
        for _ in range(3):
            eng.run_round()
        before = BK.kernel_compiles()
        for _ in range(3):
            eng.run_round()
        assert BK.kernel_compiles() == before

    def test_depth_churn_zero_recompiles_at_64_clients(self):
        """ACCEPTANCE: once the (width, bucket) cache is warm, reassigning
        every client to a FRESH depth must compile nothing new — depth is
        a runtime kernel argument, not a jit static. The whole fleet moves
        through one depth per round (cohort size, and therefore the
        bucket, is pinned at 64), so the only thing that changes between
        rounds is the depth the pre-refactor path specialized on."""
        cfg = _cfg(n_layers=3, d_model=44, n_heads=2, n_kv_heads=2,
                   head_dim=22, d_ff=88)    # unique cfg => cold jit keys
        eng = _engine("ssfl", cfg=cfg, n_clients=64, sample_frac=1.0,
                      batch_size=8)
        fleet = eng.state.fleet
        fleet.capacity = np.full_like(fleet.capacity, cfg.split_stack_len)
        depths = []
        for d in range(1, cfg.split_stack_len + 1):
            fleet.depths = np.full_like(fleet.depths, d)
            fleet.feasible = fleet.depths <= fleet.capacity
            if d == 1:                      # warm the (width, bucket) cache
                eng.run_round()
                before = BK.kernel_compiles()
            else:                           # fresh depth, same bucket
                assert np.isfinite(eng.run_round()["loss"])
                depths.append(d)
        assert len(depths) >= 2             # the depths really did move
        assert BK.kernel_compiles() == before

    def test_cross_tier_fusion_keeps_compile_key(self):
        """ACCEPTANCE: cross-tier fusion must not re-widen the compile
        key. The fused update (``tpgf.fuse_tiers`` + the fused optimizer
        state) is post-kernel work on replicated trees — no new
        registered kernel, nothing depth- or cohort-shape-keyed — so a
        64-client mixed-width run under the DEFAULT ``cross_tier="fused"``
        with per-round depth churn still compiles at most
        O(widths x buckets) programs, and the warm cache absorbs further
        churn with zero new compiles."""
        cfg = _cfg(n_layers=3, d_model=52, n_heads=2, n_kv_heads=2,
                   head_dim=26, d_ff=104)  # unique cfg => cold jit keys
        eng = _engine("ssfl", cfg=cfg, n_clients=64, sample_frac=0.8,
                      batch_size=8, width_tiers=(0.5, 1.0))
        assert eng.cross_tier == "fused"
        assert (eng.state.fleet.widths < 1.0).any()
        fleet = eng.state.fleet
        fleet.capacity = np.full_like(fleet.capacity, cfg.split_stack_len)
        widths, buckets, keys = set(), set(), set()
        strat, orig = eng.strategy, type(eng.strategy).cohorts

        def spy(self, engine, ctx):
            out = orig(self, engine, ctx)
            for d, ids in out.items():
                for w, gids in type(self)._width_groups(engine, ids):
                    b = engine.bucket_for(len(gids))
                    widths.add(w), buckets.add(b)
                    keys.add((w, b))
            return out

        def churn(r):   # the whole fleet hops to a fresh depth each round
            fleet.depths = np.full_like(fleet.depths,
                                        1 + r % cfg.split_stack_len)
            fleet.feasible = fleet.depths <= fleet.capacity

        strat.cohorts = spy.__get__(strat)
        before = BK.kernel_compiles()
        for r in range(4):
            churn(r)
            assert np.isfinite(eng.run_round()["loss"])
        compiles = BK.kernel_compiles() - before
        assert len(widths) == 2              # mixed tiers really fused
        assert compiles <= len(keys)         # one program per live key
        assert compiles <= len(widths) * len(buckets)
        warm = BK.kernel_compiles()
        for r in range(4, 6):
            churn(r)
            eng.run_round()
        assert BK.kernel_compiles() == warm


# ------------------------------------------------------------- properties
#
# Hypothesis guard: same idea as tests/test_core.py's module-level
# ``pytest.importorskip("hypothesis")``, but scoped to this class so the
# rest of the module still runs on images without hypothesis.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    S = settings(max_examples=25, deadline=None)

    @st.composite
    def _bucket_case(draw):
        n = draw(st.integers(1, 12))
        ladder = draw(st.sampled_from(
            [None, (), (1, 2, 4, 8, 16, 32), (3, 5, 9, 17)]))
        mult = draw(st.sampled_from([1, 2, 4, 8]))
        avail = np.array(draw(st.lists(st.booleans(), min_size=n,
                                       max_size=n)), bool)
        return n, BK.bucket_size(n, ladder, multiple_of=mult), mult, avail

    class TestPaddedSlotProperties:
        """The padded-slot contract, as properties over random cohort
        sizes, ladder choices and validity masks: padding must be a
        numerical no-op for the pooled means, the freeze gate, and
        ``aggregate_weighted``."""

        @S
        @given(case=_bucket_case(), seed=st.integers(0, 10**6))
        def test_pooled_mean_ignores_pad_contents(self, case, seed):
            n, bucket, mult, avail = case
            assert bucket >= n and bucket % mult == 0
            rng = np.random.default_rng(seed)
            g = rng.normal(size=(bucket, 3)).astype(np.float32)
            garbage = g.copy()
            garbage[n:] = rng.normal(size=(bucket - n, 3)) * 1e6
            valid = jnp.asarray(np.arange(bucket) < n)
            a = BK.masked_slot_mean({"g": jnp.asarray(g)}, valid)["g"]
            b = BK.masked_slot_mean({"g": jnp.asarray(garbage)}, valid)["g"]
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-12)
            # and the bucket mean equals the unpadded cohort mean
            c = BK.masked_slot_mean({"g": jnp.asarray(g[:n])},
                                    jnp.ones(n, bool))["g"]
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-6, atol=1e-7)

        @S
        @given(case=_bucket_case())
        def test_freeze_gate_never_unfrozen_by_padding(self, case):
            n, bucket, _, avail = case
            valid = jnp.asarray(np.arange(bucket) < n)
            # contract: avail forced False on padding — but the gate must
            # hold even with a hostile True there (valid guards it)
            for pad_avail in (False, True):
                pav = BK.pad_rows(avail, bucket, fill=pad_avail)
                got = bool(BK.freeze_gate(jnp.asarray(pav), valid))
                assert got == bool(np.any(avail))

        @S
        @given(case=_bucket_case(), seed=st.integers(0, 10**6))
        def test_aggregate_weighted_ignores_masked_rows(self, case, seed):
            n, bucket, _, avail = case
            cfg = _cfg()
            rng = np.random.default_rng(seed)
            L = cfg.split_stack_len
            sname = SN.split_stack_name(cfg)
            gl = {sname: {"w": jnp.asarray(
                      rng.normal(size=(L, 4)).astype(np.float32))},
                  "head": {"w": jnp.asarray(
                      rng.normal(size=(4,)).astype(np.float32))}}
            stack = {sname: {"w": rng.normal(
                         size=(bucket, L, 4)).astype(np.float32)},
                     "head": {"w": rng.normal(
                         size=(bucket, 4)).astype(np.float32)}}
            garbage = jax.tree.map(np.copy, stack)
            garbage[sname]["w"][n:] *= 1e6
            garbage["head"]["w"][n:] *= 1e6
            depths = rng.integers(1, L + 1, bucket)
            w = rng.uniform(0.1, 1.0, bucket).astype(np.float32)
            mask = np.arange(bucket) < n
            from repro.core import aggregation as AGG
            a = AGG.aggregate_weighted(cfg, gl, jax.tree.map(jnp.asarray,
                                                             stack),
                                       depths, w, mask=mask)
            b = AGG.aggregate_weighted(cfg, gl, jax.tree.map(jnp.asarray,
                                                             garbage),
                                       depths, w, mask=mask)
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           atol=1e-12)
else:   # pragma: no cover - hypothesis in [dev] extras, absent on tier-1
    class TestPaddedSlotProperties:
        def test_padded_slot_properties(self):
            pytest.skip("hypothesis not installed")


class TestFleetSmoke:
    @pytest.mark.parametrize("method", ["ssfl", "sfl", "dfl", "fedavg",
                                        "fedavgm", "hasfl", "unstable"])
    def test_64_client_round(self, method):
        eng = _engine(method, n_clients=64, sample_frac=0.5)
        rec = eng.run_round()
        assert np.isfinite(rec["loss"]) or method == "unstable"
        assert eng.state.round_idx == 1

"""Cross-tier TPGF fusion properties (``tpgf.fuse_tiers``).

The fused update's exactness guarantees, as hypothesis properties over
random (widths, depths, cohort sizes):

  (a) a single width-1.0 tier fuses to bit-exactly what today's
      ``fuse_gradients`` path produced — the full-width pipeline is
      unchanged by the cross-tier stage;
  (b) a coordinate kept by exactly one tier gets that tier's gradient
      exactly — absent tiers never dilute it (the divide-before-multiply
      normalizer: ``w/w == 1.0`` in IEEE);
  (c) the fused update is invariant to the caller's tier ordering
      (canonical width sort inside ``fuse_tiers``);
  (d) zero-weight tiers are bit-exact no-ops, in gradient AND delta mode
      (the frozen-cohort contract the ssfl strategy leans on).

"Bit-exact" throughout is ``np.testing.assert_array_equal`` — IEEE ``==``,
which identifies the +/-0.0 flips float accumulation can introduce.

Hypothesis ships in the [dev] extras; without it this module skips clean
(the test_bucketing.py guard pattern).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core import supernet as SN
from repro.core import tpgf as T
from repro.models import model as M

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _cfg():
    return base.get_reduced("vit16_cifar").replace(
        n_layers=3, d_model=24, n_heads=2, n_kv_heads=2, head_dim=12,
        d_ff=48, image_size=16, n_classes=6)


CFG = _cfg()
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
LADDER = (0.25, 0.5, 0.75, 1.0)


def _grad_like(tree, rng):
    return jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)


def _client_view(d):
    return SN.split_params(CFG, PARAMS, d)[0]


def _tree_equal(a, b, msg=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


if HAVE_HYPOTHESIS:
    S = settings(max_examples=100, deadline=None)

    @st.composite
    def _tier_case(draw):
        """Random (depth, [(width, weight, cohort_size)]) with DISTINCT
        widths — ``fuse_tiers`` pins equal-width tiers to caller order
        (two-term adds commute bit-exactly), so ordering properties draw
        distinct tiers, like the strategy's ``_width_groups`` emits."""
        d = draw(st.integers(1, CFG.split_stack_len))
        widths = sorted(draw(st.sets(st.sampled_from(LADDER), min_size=2,
                                     max_size=4)))
        tiers = [(w,
                  draw(st.floats(0.05, 50.0)),
                  draw(st.integers(1, 4)))
                 for w in widths]
        return d, tiers, draw(st.integers(0, 10**6))

    def _make_tiers(d, specs, seed):
        """Per-tier gradient = mean of ``cohort_size`` random client grads
        on the tier's width slice (what a sub-cohort kernel pools)."""
        rng = np.random.default_rng(seed)
        full = _client_view(d)
        out = []
        for w, mass, csize in specs:
            view = SN.slice_width(CFG, full, w)
            grads = [_grad_like(view, rng) for _ in range(csize)]
            g = jax.tree.map(lambda *xs: sum(xs) / len(xs), *grads)
            out.append(T.TierUpdate(w, np.float32(mass), g))
        return out

    class TestFuseTierProperties:

        @S
        @given(d=st.integers(1, CFG.split_stack_len),
               w_client=st.floats(0.05, 0.95),
               mass=st.floats(0.05, 50.0),
               seed=st.integers(0, 10**6))
        def test_single_full_width_tier_is_fuse_gradients(
                self, d, w_client, mass, seed):
            """(a) width=1.0 single tier: fuse_tiers is a bit-exact
            identity on the Eq. 4 ``fuse_gradients`` output — the legacy
            full-width path survives the cross-tier stage unchanged."""
            rng = np.random.default_rng(seed)
            view = _client_view(d)
            g = T.fuse_gradients(_grad_like(view, rng),
                                 _grad_like(view, rng),
                                 jnp.float32(w_client))
            fused = T.fuse_tiers(CFG, [T.TierUpdate(1.0, np.float32(mass),
                                                    g)])
            _tree_equal(fused, g, "single-tier width=1.0 identity")

        @S
        @given(case=_tier_case())
        def test_single_holder_coordinate_is_undiluted(self, case):
            """(b) on every plan leaf, the channels beyond the second-
            widest tier's keep are held ONLY by the widest tier — the
            fused value there must be that tier's gradient, exactly."""
            d, specs, seed = case
            tiers = _make_tiers(d, specs, seed)
            fused = T.fuse_tiers(CFG, tiers)
            top = tiers[-1]                       # specs sorted by width
            runner_up = tiers[-2]
            plan = SN.width_plan(CFG, 1.0)
            keep_lo = SN.width_keep_sizes(CFG, runner_up.width)
            lifted = SN.widen_width(CFG, top.tree, top.width)
            keep_hi = SN.width_keep_sizes(CFG, top.width)
            flat_f, _ = jax.tree_util.tree_flatten_with_path(fused)
            flat_g = jax.tree_util.tree_flatten_with_path(lifted)[0]
            checked = 0
            for (path, x), (_, g) in zip(flat_f, flat_g):
                name = SN._leaf_name(path)
                if name not in plan or keep_lo[name] >= keep_hi[name]:
                    continue
                ax, _ = plan[name]
                axis = x.ndim + ax
                sl = tuple(
                    slice(keep_lo[name], keep_hi[name]) if i == axis
                    else slice(None) for i in range(x.ndim))
                np.testing.assert_array_equal(np.asarray(x[sl]),
                                              np.asarray(g[sl]),
                                              err_msg=str(name))
                checked += 1
            assert checked > 0, "no single-holder band exercised"

        @S
        @given(case=_tier_case(), perm_seed=st.integers(0, 10**6))
        def test_order_invariance(self, case, perm_seed):
            """(c) any permutation of the tier list fuses to the same
            bits — the canonical width sort inside fuse_tiers."""
            d, specs, seed = case
            tiers = _make_tiers(d, specs, seed)
            perm = np.random.default_rng(perm_seed).permutation(len(tiers))
            a = T.fuse_tiers(CFG, tiers)
            b = T.fuse_tiers(CFG, [tiers[i] for i in perm])
            _tree_equal(a, b, f"perm={perm}")

        @S
        @given(case=_tier_case(), zw=st.sampled_from(LADDER),
               delta=st.booleans())
        def test_zero_weight_tier_is_noop(self, case, zw, delta):
            """(d) a weight-0 tier changes nothing, bit for bit — in
            gradient mode and in delta (server/moments) mode; and a fully
            zero-weight fusion in delta mode returns ``base`` exactly
            (the frozen-server invariant)."""
            d, specs, seed = case
            tiers = _make_tiers(d, specs, seed)
            rng = np.random.default_rng(seed + 1)
            dead = T.TierUpdate(
                zw, np.float32(0.0),
                _grad_like(SN.slice_width(CFG, _client_view(d), zw), rng))
            basep = None if not delta \
                else _grad_like(_client_view(d), rng)
            a = T.fuse_tiers(CFG, tiers, base=basep)
            b = T.fuse_tiers(CFG, tiers + [dead], base=basep)
            _tree_equal(a, b, "zero-weight tier no-op")
            if delta:
                allz = [t._replace(weight=np.float32(0.0)) for t in tiers]
                frozen = T.fuse_tiers(CFG, allz, base=basep)
                _tree_equal(frozen, basep, "all-frozen delta == base")

else:   # pragma: no cover - hypothesis in [dev] extras, absent on tier-1
    class TestFuseTierProperties:
        def test_fuse_tier_properties(self):
            pytest.skip("hypothesis not installed")


class TestFusedStrategyWiring:
    """Non-hypothesis smoke: the strategy threading contract."""

    def test_mixed_cohort_single_fused_update(self):
        """A mixed-width ssfl cohort under the default ``cross_tier=
        "fused"`` produces ONE server payload per cohort and finite
        losses; the chained comparator is reachable via the knob."""
        from repro.federated import Engine
        ef = Engine(CFG, 8, "ssfl", seed=0, lr=0.3, local_steps=1,
                    batch_size=4, width_tiers=(0.5, 1.0))
        ec = Engine(CFG, 8, "ssfl", seed=0, lr=0.3, local_steps=1,
                    batch_size=4, width_tiers=(0.5, 1.0),
                    cross_tier="chained")
        assert ef.cross_tier == "fused" and ec.cross_tier == "chained"
        widths = ef.state.fleet.widths
        assert (widths < 1.0).any() and (widths >= 1.0).any()
        a, b = ef.run_round(), ec.run_round()
        assert np.isfinite(a["loss"]) and np.isfinite(b["loss"])
        # the two modes agree on accounting but not (in general) on bits
        assert a["comm_mb"] == b["comm_mb"]

    def test_cross_tier_knob_validated(self):
        from repro.federated import Engine
        with pytest.raises(ValueError, match="cross_tier"):
            Engine(CFG, 4, "ssfl", cross_tier="nope")

"""FL004 corpus: nondeterminism on the round path. Parsed, never run."""
# fleetlint: scope=fleet
import random
import time

import numpy as np
from numpy.random import default_rng


def drifting_round(state):
    stamp = time.time()                  # FL004: wall clock on round path
    jitter = np.random.rand()            # FL004: hidden global numpy stream
    rng = np.random.default_rng()        # FL004: unseeded -> unsaveable
    rng2 = default_rng()                 # FL004: same, bare import form
    pick = random.random()               # FL004: stdlib global stream
    return stamp, jitter, rng, rng2, pick

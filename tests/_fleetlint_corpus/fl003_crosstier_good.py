"""FL003 corpus: a cross-tier fusion kernel honoring the contract —
axis names flow from ``axis_name``, specs cover every array in and out.
(Depth is a runtime array in the real kernels, not a jit static; this
fixture keeps a static ``d`` only to exercise FL003's arity counting.)
Parsed, never run."""
import jax.numpy as jnp
from jax import lax


def _fuse_specs(axes, *arrays):
    in_specs = (None, None)              # one per array argument
    out_specs = (None,)                  # one per output leaf
    return in_specs, out_specs


@register_kernel(n_static=5, specs=_fuse_specs)  # noqa: F821 — corpus
def fuse_kernel(cfg, d, opt, steps, width, tier_stack, tier_mass,
                axis_name=None):
    fused = jnp.sum(jnp.where(tier_mass > 0, tier_stack, 0.0))
    if axis_name is not None:
        fused = lax.psum(fused, axis_name)   # axis flows from the param
    return fused

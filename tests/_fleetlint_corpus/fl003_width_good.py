"""FL003 corpus: a width-keyed kernel honoring the contract — axis
names flow from ``axis_name``, specs cover every array in and out.
(Depth is a runtime array in the real kernels, not a jit static; this
fixture keeps a static ``d`` only to exercise FL003's arity counting.)
Parsed, never run."""
import jax.numpy as jnp
from jax import lax


def _width_specs(axes, *arrays):
    in_specs = (None, None)              # one per array argument
    out_specs = (None, None)             # one per output leaf
    return in_specs, out_specs


@register_kernel(n_static=5, specs=_width_specs)  # noqa: F821 — corpus
def width_kernel(cfg, d, opt, steps, width, cstack, valid, axis_name=None):
    pooled = jnp.sum(jnp.where(valid, cstack, 0.0))
    if axis_name is not None:
        pooled = lax.psum(pooled, axis_name)   # axis flows from the param
    return pooled, valid

"""FL005 corpus: Strategy hook signature drift. Parsed, never run."""


@register_strategy("corpus-bad")  # noqa: F821 — corpus, parsed only
class DriftingStrategy:
    def init_round(self, engine, context):        # FL005: must be ctx
        pass

    def cohort_step(self, engine, ctx, ws, d):    # FL005: missing ids
        pass

    def comm_cost(self, engine, d, available, ids):   # FL005: ids no default
        return 0.0


class DriftingChild(DriftingStrategy):
    def fold_server(self, engine, ws, d, ids, res, extra):  # FL005: extra
        pass

    def aggregate(self, engine, workspace):       # FL005: must be ws
        pass

"""FL003 corpus: width-keyed kernels that break the axis-name /
spec-coverage contract (static ``d`` kept only for FL003 arity
counting — real kernels take depth as a runtime array). Parsed, never
run."""
import jax.numpy as jnp
from jax import lax


def _width_specs(axes, *arrays):
    in_specs = (None, None)              # covers both array arguments...
    out_specs = (None,)                  # ...but only 1 of 2 outputs
    return in_specs, out_specs


@register_kernel(n_static=5, specs=_width_specs)  # noqa: F821 — corpus
def width_kernel(cfg, d, opt, steps, width, cstack, valid, axis_name=None):
    pooled = lax.pmean(jnp.sum(cstack), "fleet")   # FL003: hard-coded axis
    return pooled, valid


@register_kernel(n_static=5)  # noqa: F821 — FL003: no specs= declared
def width_kernel_specless(cfg, d, opt, steps, width, cstack,
                          axis_name=None):
    return jnp.sum(cstack)

"""FL005 corpus: conforming Strategy implementations pass. Parsed, never
run. Both comm_cost forms — the 3-arg base and the ids= probe — are legal."""


@register_strategy("corpus-good")  # noqa: F821 — corpus, parsed only
class ConformingStrategy:
    def init_round(self, engine, ctx):
        pass

    def cohorts(self, engine, ctx):
        return []

    def cohort_step(self, engine, ctx, ws, d, ids):
        pass

    def fold_server(self, engine, ws, d, ids, res):
        pass

    def aggregate(self, engine, ws):
        pass

    def comm_cost(self, engine, d, available):
        return 0.0


class ConformingChild(ConformingStrategy):
    def prepare_fleet(self, cfg, fleet, device_model=None):
        return fleet

    def participation_process(self, cfg, n_clients, seed):
        return None

    def comm_cost(self, engine, d, available, ids=None):
        return 0.0

    def helper_not_a_hook(self, whatever, args):   # non-hook: ignored
        pass

"""FL004 corpus: explicit seeded streams pass. Parsed, never run."""
# fleetlint: scope=fleet
import numpy as np


def seeded_round(seed, state):
    rng = np.random.default_rng(seed + 13)      # seeded, offset stream
    gen = np.random.Generator(np.random.PCG64(seed))
    schedule = state["round_idx"] * 2           # time from round counter
    return rng.random(), gen.random(), schedule

"""FL002 corpus: width-sliced slot reductions, masked / blessed / off the
slot axis. Parsed, never run."""
# fleetlint: scope=fleet
import jax.numpy as jnp

from repro.federated import bucketing as BK


def fold_width_groups(widened_stack, keep_mask, valid, gates,
                      axis_name=None):
    row = valid.reshape((-1, 1, 1))
    num = jnp.sum(jnp.where(row, widened_stack, 0.0), axis=0)
    den = BK.slot_sum(keep_mask * valid.reshape((-1, 1)), axis_name)
    gate = BK.freeze_gate(gates, valid, axis_name)
    per_coord = jnp.sum(widened_stack, axis=-1)   # not the slot axis
    return num, den, gate, per_coord

"""FL002 corpus: masked / blessed / off-axis reductions all pass.
Parsed, never run."""
# fleetlint: scope=fleet
import jax.numpy as jnp

from repro.federated import bucketing as BK


def masked(stack, valid, gates, axis_name=None):
    row = valid.reshape((-1, 1))
    total = jnp.sum(jnp.where(row, stack, 0.0), axis=0)   # where-guarded
    blessed = BK.slot_sum(stack * row, axis_name)         # blessed primitive
    center = BK.masked_slot_mean(stack, valid, axis_name)
    gate = BK.freeze_gate(gates, valid, axis_name)
    per_slot = jnp.sum(stack, axis=1)                     # not the slot axis
    suppressed = jnp.mean(stack, axis=0)  # fleetlint: disable=FL002 — corpus: caller guarantees no padded slots here
    return total, blessed, center, gate, per_slot, suppressed

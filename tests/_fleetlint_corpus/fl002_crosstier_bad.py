"""FL002 corpus: cross-tier fusion reductions over the tier axis count
dead (frozen / zero-mass) tiers into the fused update. Parsed, never
run."""
# fleetlint: scope=fleet
import jax.numpy as jnp


def fuse_tier_stack(tier_stack, tier_mass, frozen):
    # per-tier TPGF outputs lifted to full width and stacked on axis 0:
    # the tier axis needs the live mask before any reduction, or frozen
    # tiers' zero-extended slices dilute the coordinates they never held
    den = jnp.sum(tier_mass, axis=0)           # FL002: dead tiers count
    fused = jnp.mean(tier_stack, axis=0)       # FL002: dilutes over frozen
    any_live = jnp.any(tier_mass > 0)          # FL002: pad tier can flip it
    return fused / den, any_live

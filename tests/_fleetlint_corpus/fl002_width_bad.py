"""FL002 corpus: width-sliced slot reductions leak padded slots.
Parsed, never run."""
# fleetlint: scope=fleet
import jax.numpy as jnp


def fold_width_groups(widened_stack, keep_mask, gates):
    # a width-w sub-cohort's zero-embedded client stacks: the pruned-coord
    # zeros are safe only under the per-coordinate denominators — the SLOT
    # axis still needs the valid mask either way
    num = jnp.sum(widened_stack, axis=0)       # FL002: pads leak in
    den = jnp.mean(keep_mask, axis=0)          # FL002: dilutes over pads
    any_narrow = jnp.any(gates)                # FL002: a pad can flip it
    return num / den, any_narrow

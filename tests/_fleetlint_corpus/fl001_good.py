"""FL001 corpus: the same ops are fine at host level, and kernels that stay
on-device pass. Parsed, never run."""
import jax
import jax.numpy as jnp
from jax import lax


@register_kernel(n_static=1, specs=None)  # noqa: F821 — corpus, parsed only
def clean_kernel(cfg, xs, valid, axis_name=None):
    total = jnp.sum(jnp.where(valid, xs, 0.0))
    gate = jnp.where(valid.any(axis=1), 1.0, 0.0)
    return total, gate


def clean_body(carry, x):
    return carry + x, x


def run(xs, out):
    # host-level syncs OUTSIDE kernel/scan bodies are the one-per-round
    # sync in _finish_aggregation — not flagged.
    ys = lax.scan(clean_body, 0.0, xs)
    host = float(out[0])
    return ys, host, jax.device_get(out)

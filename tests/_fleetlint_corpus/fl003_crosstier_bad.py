"""FL003 corpus: cross-tier fusion kernels that break the axis-name /
spec-coverage contract (static ``d`` kept only for FL003 arity
counting — real kernels take depth as a runtime array). Parsed, never
run."""
import jax.numpy as jnp
from jax import lax


def _fuse_specs(axes, *arrays):
    in_specs = (None,)                   # covers only 1 of 2 arrays
    out_specs = (None,)
    return in_specs, out_specs


@register_kernel(n_static=5, specs=_fuse_specs)  # noqa: F821 — corpus
def fuse_kernel(cfg, d, opt, steps, width, tier_stack, tier_mass,
                axis_name=None):
    fused = lax.psum(jnp.sum(tier_stack), "fleet")  # FL003: hard-coded axis
    return fused


@register_kernel(n_static=5)  # noqa: F821 — FL003: no specs= declared
def fuse_kernel_specless(cfg, d, opt, steps, width, tier_stack,
                         axis_name=None):
    return jnp.sum(tier_stack)

"""FL002 corpus: raw cross-slot reductions. Parsed, never run."""
# fleetlint: scope=fleet
import jax.numpy as jnp


def pollute(stack, gates):
    total = jnp.sum(stack, axis=0)       # FL002: padded slots leak in
    center = jnp.mean(stack, axis=0)     # FL002: mean dilutes over pads
    hit = jnp.any(gates)                 # FL002: a pad can flip the gate
    frozen = jnp.all(gates, axis=0)      # FL002: axis-0 gate, same hazard
    return total, center, hit, frozen

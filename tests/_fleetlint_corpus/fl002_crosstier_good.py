"""FL002 corpus: cross-tier fusion reductions, masked / blessed / off
the tier axis. Parsed, never run."""
# fleetlint: scope=fleet
import jax.numpy as jnp

from repro.federated import bucketing as BK


def fuse_tier_stack(tier_stack, tier_mass, live, axis_name=None):
    keep = live.reshape((-1, 1))
    den = jnp.sum(jnp.where(keep, tier_mass, 0.0), axis=0)
    fused = jnp.sum(jnp.where(keep[..., None], tier_stack, 0.0), axis=0)
    gate = BK.freeze_gate(tier_mass > 0, live, axis_name)
    per_coord = jnp.sum(tier_stack, axis=-1)   # not the tier axis
    return fused / den, gate, per_coord

"""FL003 corpus: axis names flow from the axis_name parameter and specs
cover every array in and out. Parsed, never run."""
import jax.numpy as jnp
from jax import lax


def _covered_specs(axes, *arrays):
    in_specs = (None, None)              # one per array argument
    out_specs = (None, None)             # one per output leaf
    return in_specs, out_specs


@register_kernel(n_static=1, specs=_covered_specs)  # noqa: F821 — corpus
def covered_kernel(cfg, xs, valid, axis_name=None):
    s = jnp.sum(jnp.where(valid, xs, 0.0))
    if axis_name is not None:
        s = lax.psum(s, axis_name)       # axis flows from the parameter
    return s, valid

"""FL001 corpus: host syncs inside compiled kernel code. Parsed, never run."""
import numpy as np

import jax
from jax import lax


@register_kernel(n_static=1, specs=None)  # noqa: F821 — corpus, parsed only
def leaky_kernel(cfg, xs, valid, axis_name=None):
    total = float(xs.sum())              # FL001: float() on a traced value
    flag = bool(valid.any())             # FL001: bool() truthiness sync
    host = np.asarray(xs)                # FL001: host materialization
    peek = xs.item()                     # FL001: .item() sync
    jax.device_get(xs)                   # FL001: explicit device->host pull
    return total, flag, host, peek


def scan_body(carry, x):
    bad = float(x)                       # FL001: sync inside a scan body
    return carry + bad, x


def run(xs):
    return lax.scan(scan_body, 0.0, xs)

"""FL003 corpus: axis-name and pspec-coverage violations. Parsed, never run."""
import jax.numpy as jnp
from jax import lax


def _skewed_specs(axes, *arrays):
    in_specs = (None, None, None)        # 3 specs for a 2-array kernel
    out_specs = (None,)                  # 1 spec for a 2-output kernel
    return in_specs, out_specs


@register_kernel(n_static=1, specs=_skewed_specs)  # noqa: F821 — corpus
def skewed_kernel(cfg, xs, valid, axis_name=None):
    s = lax.psum(jnp.sum(xs), "clients")   # FL003: hard-coded axis name
    return s, valid


@register_kernel(n_static=1)  # noqa: F821 — FL003: no specs= declared
def specless_kernel(cfg, xs, axis_name=None):
    return jnp.sum(xs)


@register_kernel(n_static=1, specs=_skewed_specs)  # noqa: F821 — corpus
def axisless_kernel(cfg, xs, valid):     # FL003: no axis_name parameter
    return xs, valid
